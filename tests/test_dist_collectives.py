"""Fast single-device tests for repro.dist (bucketing, padding, wire
accounting, the world-size-1 degenerate collectives) plus the 8-emulated-
device packed-vs-unpacked wire parity suite (subprocess, like
tests/test_multidevice.py, because XLA_FLAGS must be set before jax
initializes)."""
import os
import subprocess
import sys
import textwrap
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import lattice as L
from repro.dist.collectives import (QSyncConfig, _bucketize, _encode_packed,
                                    _payload_bytes, _sides, _unbucketize,
                                    allgather_allreduce_mean,
                                    butterfly_allreduce_mean,
                                    flat_size_padded, rh_reduce_scatter_mean,
                                    wire_bytes_allgather,
                                    wire_bytes_butterfly, wire_bytes_rh)
from repro.dist.fsdp import (FSDPConfig, TELE_WIDTH, make_fsdp_gather,
                             pad_to_shardable, wire_bytes_bwd)


@pytest.mark.parametrize("rotate", [False, True])
@pytest.mark.parametrize("n", [1024, 1000, 255, 4096, 1])
def test_bucketize_roundtrip(rotate, n):
    cfg = QSyncConfig(q=16, bucket=256, rotate=rotate)
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    b = _bucketize(x, cfg)
    assert b.shape == (flat_size_padded(n, cfg) // cfg.bucket, cfg.bucket)
    back = _unbucketize(b, n, cfg)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-4,
                               atol=1e-5)


def test_bucketize_pads_with_zeros_unrotated():
    cfg = QSyncConfig(q=16, bucket=64, rotate=False)
    x = jnp.arange(70, dtype=jnp.float32)
    b = _bucketize(x, cfg)
    assert b.shape == (2, 64)
    np.testing.assert_array_equal(np.asarray(b.reshape(-1)[70:]),
                                  np.zeros(128 - 70, np.float32))


def test_flat_size_padded_edges():
    cfg = QSyncConfig(q=16, bucket=256)
    assert flat_size_padded(256, cfg) == 256
    assert flat_size_padded(257, cfg) == 512
    assert flat_size_padded(1, cfg) == 256
    # also accepts a raw bucket size
    assert flat_size_padded(100, 32) == 128


def test_pad_to_shardable_edges():
    # n < dp*bucket pads up to one bucket per rank
    assert pad_to_shardable(10, 8, 64) == 8 * 64
    assert pad_to_shardable(8 * 64, 8, 64) == 8 * 64
    assert pad_to_shardable(8 * 64 + 1, 8, 64) == 2 * 8 * 64
    # degenerate sizes never return 0
    assert pad_to_shardable(0, 1, 1) == 1
    assert pad_to_shardable(1, 1, 1) == 1


def test_wire_bytes_consistent_with_lattice():
    cfg = QSyncConfig(q=16, bucket=4096)          # 4 bits/coord
    n = 1 << 16
    padded = flat_size_padded(n, cfg)
    payload = L.wire_bytes(padded, cfg.bits) + 4 * (padded // cfg.bucket)
    assert wire_bytes_butterfly(n, 8, cfg) == 3 * payload
    assert wire_bytes_allgather(n, 8, cfg) == 7 * payload
    assert wire_bytes_butterfly(n, 1, cfg) == 0
    assert wire_bytes_allgather(n, 1, cfg) == 0
    # q=256 doubles the per-coordinate bits
    cfg8 = QSyncConfig(q=256, bucket=4096)
    assert (wire_bytes_butterfly(n, 8, cfg8) >
            1.9 * wire_bytes_butterfly(n, 8, cfg))


def test_qsync_config_validation():
    with pytest.raises(ValueError):
        QSyncConfig(q=1)
    with pytest.raises(ValueError):
        QSyncConfig(bucket=48)            # not a power of two
    assert QSyncConfig(q=16).bits == 4
    assert QSyncConfig(q=256).bits == 8


def _world1(fn, x, y_b, cfg):
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
             check_vma=False)
    def f(xl):
        out, aux = fn(xl, y_b, jax.random.PRNGKey(7), "data", cfg)
        return out, aux.fails

    return jax.jit(f)(x)


@pytest.mark.parametrize("fn", [allgather_allreduce_mean,
                                butterfly_allreduce_mean,
                                rh_reduce_scatter_mean])
def test_world1_collectives_are_near_identity(fn):
    """world==1: the 'mean' is the vector itself; butterfly/rh skip all
    rounds, the star path round-trips one lattice encode (error <= s/2)."""
    cfg = QSyncConfig(q=16, bucket=256)
    n = 512
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    y = 1.0
    y_b = jnp.full((n // cfg.bucket,), y)
    out, fails = _world1(fn, x, y_b, cfg)
    s = 2 * y / (cfg.q - 1)
    assert out.shape == (n,)
    assert float(jnp.max(jnp.abs(out - x))) <= 0.5 * s + 1e-6
    assert float(fails) == 0.0


def test_fsdp_gather_forward_and_grad_world1():
    """dp=1 gather: forward is a bf16 cast, backward 'lq' is exact (no
    quantization rounds), and telemetry arrives as the tele cotangent."""
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = FSDPConfig(axes=("data",), qcfg=QSyncConfig(q=16, bucket=64),
                     sync="lq")
    gather = make_fsdp_gather(cfg)
    w = jax.random.normal(jax.random.PRNGKey(0), (128,))
    coef = jax.random.normal(jax.random.PRNGKey(1), (128,))

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(), P()),
             out_specs=(P(), P(), P()), check_vma=False)
    def f(w, tele):
        def loss(w, tele):
            bundle = {"w": w, "y": jnp.float32(1.0),
                      "key": jax.random.PRNGKey(3), "tele": tele}
            full = gather(bundle)
            return jnp.sum(full.astype(jnp.float32) * coef)

        l, (gw, gt) = jax.value_and_grad(loss, argnums=(0, 1))(w, tele)
        return l, gw, gt

    tele0 = jnp.zeros((TELE_WIDTH,), jnp.float32)
    l, gw, gt = jax.jit(f)(w, tele0)
    np.testing.assert_allclose(np.asarray(l),
                               float(jnp.sum(w.astype(jnp.bfloat16)
                                             .astype(jnp.float32) * coef)),
                               rtol=1e-6)
    # dp=1 lq reduce-scatter has zero rounds: gradient is exact
    np.testing.assert_allclose(np.asarray(gw), np.asarray(coef), rtol=1e-2,
                               atol=1e-3)
    assert gt.shape == (TELE_WIDTH,)
    assert float(gt[1]) == 0.0            # no decode failures


# ---------------------------------------------------------------------------
# Packed wire path: exact payload accounting + parity with the jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,bucket,q", [
    (8192, 1024, 16),     # aligned
    (1000, 128, 16),      # odd d: padding slice, partial final bucket
    (12, 4, 16),          # tiny buckets: final word spans multiple buckets
    (4096, 512, 256),     # 8-bit colors
])
def test_packed_payload_matches_wire_accounting(n, bucket, q):
    """words.nbytes + sides.nbytes of the actual packed message equals
    _payload_bytes, and the per-topology wire_bytes_* follow from it."""
    cfg = QSyncConfig(q=q, bucket=bucket, packed=True)
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    xb = _bucketize(x, cfg)
    nb = xb.shape[0]
    s = _sides(jnp.full((nb,), 1.0), cfg)
    u = L.shared_offset(jax.random.PRNGKey(1), xb.shape)
    words = _encode_packed(xb, s[:, 0], u, cfg)
    sides = s[:, 0]
    assert words.dtype == jnp.uint32
    assert words.nbytes + sides.nbytes == _payload_bytes(n, cfg)
    assert wire_bytes_butterfly(n, 8, cfg) == 3 * _payload_bytes(n, cfg)
    assert wire_bytes_allgather(n, 8, cfg) == 7 * _payload_bytes(n, cfg)


def test_packed_payload_8x_reduction_at_q16():
    """The headline claim: 4-bit colors -> 8x smaller than f32 on the wire
    (the sides sidecar is 1 f32 per bucket, <0.1% at bucket=4096)."""
    cfg = QSyncConfig(q=16, bucket=4096, packed=True)
    n = 1 << 16
    fp32 = 4 * flat_size_padded(n, cfg)
    assert fp32 / _payload_bytes(n, cfg) > 7.9


def test_unpacked_wire_bytes_are_uint32_colors():
    """packed=False accounting reflects the jnp fallback's real payload:
    one uint32 color per coordinate, no sides sidecar."""
    cfg_p = QSyncConfig(q=16, bucket=1024, packed=True)
    cfg_u = QSyncConfig(q=16, bucket=1024, packed=False)
    n = 8192
    assert _payload_bytes(n, cfg_u) == 4 * n
    assert _payload_bytes(n, cfg_u) > 7 * _payload_bytes(n, cfg_p)
    assert wire_bytes_rh(n, 8, cfg_u) == 4 * (n // 2 + n // 4 + n // 8)


def test_wire_bytes_rh_sums_halving_rounds():
    cfg = QSyncConfig(q=16, bucket=512)
    n = 1 << 15
    padded = flat_size_padded(n, cfg)
    nb = padded // cfg.bucket
    # rounds send padded/2, padded/4, padded/8 coordinates (+ their sides)
    want = sum(L.wire_bytes(padded >> r, cfg.bits) + 4 * (nb >> r)
               for r in (1, 2, 3))
    assert wire_bytes_rh(n, 8, cfg) == want
    assert wire_bytes_rh(n, 1, cfg) == 0
    # the halving geometric series stays under one full-vector payload
    assert wire_bytes_rh(n, 8, cfg) < _payload_bytes(n, cfg)


def test_fsdp_wire_bytes_bwd_accounting():
    qc = QSyncConfig(q=16, bucket=512)
    cfg = FSDPConfig(axes=("data",), qcfg=qc)
    m = 8 * 4096
    assert wire_bytes_bwd(m, [8], cfg) == wire_bytes_rh(m, 8, qc)
    # fp32 ring psum_scatter: (ws-1)/ws of the segment in f32
    fp32 = FSDPConfig(axes=("data",), sync="fp32")
    assert wire_bytes_bwd(m, [8], fp32) == 4 * (m - m // 8)
    # lq moves ~8x fewer bytes at q=16
    assert wire_bytes_bwd(m, [8], fp32) > 7 * wire_bytes_bwd(m, [8], cfg)
    # dp=1: nothing crosses the wire
    assert wire_bytes_bwd(m, [1], cfg) == 0


@pytest.mark.parametrize("fn", [allgather_allreduce_mean,
                                butterfly_allreduce_mean,
                                rh_reduce_scatter_mean])
def test_world1_packed_matches_unpacked_bitwise(fn):
    cfg_p = QSyncConfig(q=16, bucket=256, packed=True)
    cfg_u = QSyncConfig(q=16, bucket=256, packed=False)
    n = 512
    x = jax.random.normal(jax.random.PRNGKey(3), (n,))
    y_b = jnp.full((n // 256,), 1.0)
    out_p, fails_p = _world1(fn, x, y_b, cfg_p)
    out_u, fails_u = _world1(fn, x, y_b, cfg_u)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_u))
    assert float(fails_p) == float(fails_u)


_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_8dev(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_packed_vs_unpacked_parity_8dev():
    """The tentpole acceptance check: on 8 emulated devices all three
    collectives produce bitwise-identical means and identical decode-failure
    telemetry through the packed Pallas wire path and the unpacked jnp path
    — including an odd, non-tile-aligned d (padding slice) — and detected
    failures (y too small) report identically too."""
    out = _run_8dev("""
        from functools import partial
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import (QSyncConfig,
            allgather_allreduce_mean, butterfly_allreduce_mean,
            rh_reduce_scatter_mean, flat_size_padded)
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        key = jax.random.PRNGKey(42)
        def run(fn, cfg, xs, y_b):
            @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
                     out_specs=(P("data"), P("data")), check_vma=False)
            def f(xl):
                out, aux = fn(xl.reshape(-1), y_b, key, "data", cfg)
                tele = jnp.stack([aux.fails, aux.max_dist, aux.y_next])
                return out.reshape(1, -1), tele[None]
            return jax.jit(f)(xs)
        fns = (allgather_allreduce_mean, butterfly_allreduce_mean,
               rh_reduce_scatter_mean)
        for n, bucket in ((8 * 1024, 1024), (1000, 128)):   # odd d second
            base = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 5.0
            xs = base + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (8, n))
            y = float(2 * jnp.max(jnp.abs(xs - xs.mean(0))))
            nb = flat_size_padded(n, bucket) // bucket
            y_b = jnp.full((nb,), y)
            for fn in fns:
                op, ap = run(fn, QSyncConfig(q=16, bucket=bucket, packed=True),
                             xs, y_b)
                ou, au = run(fn, QSyncConfig(q=16, bucket=bucket, packed=False),
                             xs, y_b)
                assert np.array_equal(np.asarray(op), np.asarray(ou)), \\
                    (fn.__name__, n, "mean")
                assert np.array_equal(np.asarray(ap), np.asarray(au)), \\
                    (fn.__name__, n, "aux")
                assert float(np.asarray(ap)[0, 0]) == 0.0, (fn.__name__, n)
                if fn is not rh_reduce_scatter_mean:
                    o = np.asarray(op)
                    assert np.all(o == o[0]), (fn.__name__, n, "common output")
        # decode failures must be *detected* identically.  The 1.5y distance
        # surrogate can only fire for q=2 (max decode distance is q/(q-1)*y,
        # <= 1.07y at q=16 but 2y at q=2), so the failure leg runs q=2 with
        # an undersized bound.
        n, bucket = 8 * 1024, 1024
        base = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 5.0
        xs = base + 0.5 * jax.random.normal(jax.random.PRNGKey(1), (8, n))
        y_tiny = jnp.full((n // bucket,), 1e-2)
        for fn in fns:
            _, ap = run(fn, QSyncConfig(q=2, bucket=bucket, packed=True),
                        xs, y_tiny)
            _, au = run(fn, QSyncConfig(q=2, bucket=bucket, packed=False),
                        xs, y_tiny)
            ap, au = np.asarray(ap), np.asarray(au)
            # telemetry is computed from integer coordinate deltas (one
            # correctly-rounded multiply, no FMA-contractible chain), so
            # packed and unpacked agree bitwise — including the distances
            assert np.array_equal(ap, au), fn.__name__
            assert float(ap[0, 0]) > 0, fn.__name__
        print("PACKED_PARITY_OK")
    """)
    assert "PACKED_PARITY_OK" in out


def test_anchored_collectives_8dev():
    """ISSUE 4 tentpole on 8 devices: QState(anchor=0) is bit-identical to
    the bare-y path for all three collectives, and in the drifting
    large-norm regime (|mu| ~ 1e6 >> spread) the anchored mean is strictly
    more accurate than the unanchored one at the same q/bucket/y — while
    keeping the star/butterfly common-output property."""
    out = _run_8dev("""
        from functools import partial
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.qstate import QState
        from repro.dist.collectives import (QSyncConfig,
            allgather_allreduce_mean, butterfly_allreduce_mean,
            rh_reduce_scatter_mean, flat_size_padded)
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n, bucket = 8192, 1024
        cfg = QSyncConfig(q=16, bucket=bucket)
        key = jax.random.PRNGKey(42)
        nb = flat_size_padded(n, bucket) // bucket
        mu = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 1e6
        xs = mu + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (8, n))
        exact = np.asarray(xs, np.float64).mean(0)
        y_b = jnp.full((nb,), 0.5)
        def run(fn, state):
            @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
                     out_specs=(P("data"), P("data")), check_vma=False)
            def f(xl):
                o, aux = fn(xl.reshape(-1), state, key, "data", cfg)
                return o.reshape(1, -1), jnp.stack(
                    [aux.fails, aux.max_dist])[None]
            return jax.jit(f)(xs)
        fns = (allgather_allreduce_mean, butterfly_allreduce_mean,
               rh_reduce_scatter_mean)
        for fn in fns:
            o_z, a_z = run(fn, QState(y=y_b, anchor=jnp.zeros((n,))))
            o_n, a_n = run(fn, y_b)
            assert np.array_equal(np.asarray(o_z), np.asarray(o_n)), \\
                (fn.__name__, "zero anchor != bare y")
            assert np.array_equal(np.asarray(a_z), np.asarray(a_n))
            o_a, a_a = run(fn, QState(y=y_b, anchor=mu))
            o_a = np.asarray(o_a)
            if fn is not rh_reduce_scatter_mean:
                assert np.all(o_a == o_a[0]), (fn.__name__, "common output")
            err_a = np.abs(o_a.reshape(8, -1)[:1].reshape(-1) - exact).max() \\
                if fn is not rh_reduce_scatter_mean else \\
                np.abs(o_a.reshape(-1) - exact).max()
            err_u = np.abs(np.asarray(o_n).reshape(8, -1)[:1].reshape(-1)
                           - exact).max() \\
                if fn is not rh_reduce_scatter_mean else \\
                np.abs(np.asarray(o_n).reshape(-1) - exact).max()
            assert err_a < err_u, (fn.__name__, err_a, err_u)
            assert float(np.asarray(a_a)[0, 0]) == 0.0   # no decode fails
        print("ANCHORED_COLLECTIVES_OK")
    """)
    assert "ANCHORED_COLLECTIVES_OK" in out


def test_fsdp_anchored_butterfly_8dev():
    """Anchored FSDP mode: the backward runs the butterfly with
    QState(anchor = previous decoded mean), every rank's anchor cotangent
    is the identical full-length mean (the next anchor, maintained with no
    extra comms), the w-cotangent shards are exactly its slices, and
    multi-axis per-bucket y threads through the rh chain when unanchored."""
    out = _run_8dev("""
        from functools import partial
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import QSyncConfig
        from repro.dist.fsdp import (FSDPConfig, make_fsdp_gather,
                                     tele_width, leaf_nb, TELE_WIDTH)
        mesh = jax.make_mesh((2, 4), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        qc = QSyncConfig(q=16, bucket=64)
        m = 8 * 512
        shard = m // 8
        nb = leaf_nb(m, 8, qc)
        coef = jax.random.normal(jax.random.PRNGKey(1), (m,)) + 1e5
        anchor = coef + 0.01 * jax.random.normal(jax.random.PRNGKey(2), (m,))
        w = jax.random.normal(jax.random.PRNGKey(0), (8, shard))
        # ---- anchored butterfly ----
        cfg = FSDPConfig(axes=("pod", "data"), qcfg=qc, sync="lq",
                         anchored=True)
        gather = make_fsdp_gather(cfg)
        tw = tele_width(nb, m, True)
        @partial(jax.shard_map, mesh=mesh, in_specs=(P(("pod","data")), P()),
                 out_specs=(P(("pod","data")), P(("pod","data"))),
                 check_vma=False)
        def f(wl, tele):
            def loss(wv, t):
                bundle = {"w": wv.reshape(-1),
                          "y": {"y": jnp.full((nb,), 1.0), "anchor": anchor},
                          "key": jax.random.PRNGKey(3), "tele": t}
                return jnp.sum(gather(bundle).astype(jnp.float32) * coef)
            _, (gw, gt) = jax.value_and_grad(loss, argnums=(0, 1))(wl, tele)
            return gw.reshape(1, -1), gt[None]
        gw, gt = jax.jit(f)(w, jnp.zeros((tw,)))
        gw, gt = np.asarray(gw), np.asarray(gt)
        anchors = gt[:, TELE_WIDTH + 2 * nb:]
        assert np.all(anchors == anchors[0]), "anchor must be common"
        assert np.array_equal(anchors[0], gw.reshape(-1)), \\
            "shards must be slices of the anchor/mean"
        target = np.asarray(coef)
        rel = np.abs(gw.reshape(-1) - target).max() / np.abs(target).max()
        assert rel < 1e-2, rel            # anchored: tiny error at |g|~1e5
        assert float(gt[0, 1]) == 0.0     # no decode failures
        # ---- unanchored multi-axis rh with per-bucket y ----
        cfg_rh = FSDPConfig(axes=("pod", "data"), qcfg=qc, sync="lq")
        gather_rh = make_fsdp_gather(cfg_rh)
        tw_rh = tele_width(nb)
        coef2 = jax.random.normal(jax.random.PRNGKey(4), (m,))
        y_b = jnp.full((nb,), 1.0).at[0].set(4.0)   # non-uniform buckets
        @partial(jax.shard_map, mesh=mesh, in_specs=(P(("pod","data")), P()),
                 out_specs=(P(("pod","data")), P(("pod","data"))),
                 check_vma=False)
        def f2(wl, tele):
            # per-rank loss scale => per-rank cotangents, so decoded partner
            # coords differ from local coords and dist_b is populated
            ri = jax.lax.axis_index("pod") * 4 + jax.lax.axis_index("data")
            scale = 1.0 + 0.01 * ri.astype(jnp.float32)
            def loss(wv, t):
                bundle = {"w": wv.reshape(-1), "y": y_b,
                          "key": jax.random.PRNGKey(5), "tele": t}
                return jnp.sum(gather_rh(bundle).astype(jnp.float32) * coef2) * scale
            _, (gw, gt) = jax.value_and_grad(loss, argnums=(0, 1))(wl, tele)
            return gw.reshape(1, -1), gt[None]
        gw2, gt2 = jax.jit(f2)(w, jnp.zeros((tw_rh,)))
        gw2, gt2 = np.asarray(gw2), np.asarray(gt2)
        # true mean gradient is coef2 * mean(scale) = coef2 * 1.035
        err2 = np.abs(gw2.reshape(-1) - 1.035 * np.asarray(coef2))
        # bucket 0 runs at y=4 (s=8/15, up to ~s/2 per rh round); the rest
        # at y=1 — per-bucket sides really are per bucket
        b = 64
        assert err2[:b].max() < 3 * (8/15), err2[:b].max()
        assert err2[b:].max() < 3 * (2/15), err2[b:].max()
        # per-bucket maps are identical on every rank (all-gathered back)
        assert np.all(gt2[:, TELE_WIDTH:] == gt2[:1, TELE_WIDTH:])
        assert gt2[0, TELE_WIDTH:TELE_WIDTH + nb].max() > 0   # dist_b seen
        print("FSDP_ANCHORED_OK")
    """)
    assert "FSDP_ANCHORED_OK" in out


def test_effective_bucket_matches_sharding_rule():
    """fsdp picks a reduce-scatter bucket that tiles whatever padding
    models/sharding.effective_bucket chose for small leaves."""
    from repro.dist.fsdp import _effective_bucket
    from repro.models.sharding import ShardCtx, effective_bucket
    for n in (7, 32, 100, 1000, 5000):
        for dp in (1, 2, 8):
            qcfg = QSyncConfig(q=16, bucket=512)
            ctx = ShardCtx(dp=dp, qcfg=qcfg)
            b_store = effective_bucket(n, ctx)
            m = pad_to_shardable(n, dp, b_store)
            b_rs = _effective_bucket(qcfg, m, dp)
            assert m % (dp * b_rs) == 0, (n, dp, b_store, b_rs, m)


def test_tp_psum_grad_quantized_butterfly_2dev():
    """ShardCtx.quantize_tp_grads routes the replicated-leaf gradient psum
    through the quantized butterfly (ROADMAP item): close to the exact fp32
    psum, bit-identical across tp ranks, and exact when the flag is off."""
    out = _run_8dev("""
        from functools import partial
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.models.sharding import ShardCtx, _tp_psum_grad
        from repro.dist.collectives import QSyncConfig
        mesh = jax.make_mesh((2,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n = 2048
        coef = jax.random.normal(jax.random.PRNGKey(0), (2, n))
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        def run(ctx):
            @partial(jax.shard_map, mesh=mesh, in_specs=(P(), P("model")),
                     out_specs=P("model"), check_vma=False)
            def f(xl, cl):
                def loss(v):
                    return jnp.sum(_tp_psum_grad(v, ctx, None)
                                   * cl.reshape(-1))
                return jax.grad(loss)(xl).reshape(1, -1)
            return np.asarray(jax.jit(f)(x, coef))
        exact = np.asarray(coef.sum(0))
        g_fp = run(ShardCtx(tp=2, quantize_tp_grads=False))
        assert np.allclose(g_fp[0], exact, atol=1e-5)
        g_lq = run(ShardCtx(tp=2, quantize_tp_grads=True,
                            qcfg=QSyncConfig(q=16, bucket=512)))
        assert np.array_equal(g_lq[0], g_lq[1])       # common output
        rel = np.abs(g_lq[0] - exact).max() / np.abs(exact).max()
        assert rel < 0.25, rel                        # y = 2*pmax|g| bound
        # finer color space -> smaller error
        g_lq2 = run(ShardCtx(tp=2, quantize_tp_grads=True,
                             qcfg=QSyncConfig(q=256, bucket=512)))
        rel2 = np.abs(g_lq2[0] - exact).max() / np.abs(exact).max()
        assert rel2 < rel / 4, (rel, rel2)
        print("TP_BUTTERFLY_OK")
    """)
    assert "TP_BUTTERFLY_OK" in out
