"""Fast single-device tests for repro.dist (bucketing, padding, wire
accounting, and the world-size-1 degenerate collectives)."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import lattice as L
from repro.dist.collectives import (QSyncConfig, _bucketize, _unbucketize,
                                    allgather_allreduce_mean,
                                    butterfly_allreduce_mean,
                                    flat_size_padded, rh_reduce_scatter_mean,
                                    wire_bytes_allgather,
                                    wire_bytes_butterfly)
from repro.dist.fsdp import (FSDPConfig, TELE_WIDTH, make_fsdp_gather,
                             pad_to_shardable)


@pytest.mark.parametrize("rotate", [False, True])
@pytest.mark.parametrize("n", [1024, 1000, 255, 4096, 1])
def test_bucketize_roundtrip(rotate, n):
    cfg = QSyncConfig(q=16, bucket=256, rotate=rotate)
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    b = _bucketize(x, cfg)
    assert b.shape == (flat_size_padded(n, cfg) // cfg.bucket, cfg.bucket)
    back = _unbucketize(b, n, cfg)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-4,
                               atol=1e-5)


def test_bucketize_pads_with_zeros_unrotated():
    cfg = QSyncConfig(q=16, bucket=64, rotate=False)
    x = jnp.arange(70, dtype=jnp.float32)
    b = _bucketize(x, cfg)
    assert b.shape == (2, 64)
    np.testing.assert_array_equal(np.asarray(b.reshape(-1)[70:]),
                                  np.zeros(128 - 70, np.float32))


def test_flat_size_padded_edges():
    cfg = QSyncConfig(q=16, bucket=256)
    assert flat_size_padded(256, cfg) == 256
    assert flat_size_padded(257, cfg) == 512
    assert flat_size_padded(1, cfg) == 256
    # also accepts a raw bucket size
    assert flat_size_padded(100, 32) == 128


def test_pad_to_shardable_edges():
    # n < dp*bucket pads up to one bucket per rank
    assert pad_to_shardable(10, 8, 64) == 8 * 64
    assert pad_to_shardable(8 * 64, 8, 64) == 8 * 64
    assert pad_to_shardable(8 * 64 + 1, 8, 64) == 2 * 8 * 64
    # degenerate sizes never return 0
    assert pad_to_shardable(0, 1, 1) == 1
    assert pad_to_shardable(1, 1, 1) == 1


def test_wire_bytes_consistent_with_lattice():
    cfg = QSyncConfig(q=16, bucket=4096)          # 4 bits/coord
    n = 1 << 16
    padded = flat_size_padded(n, cfg)
    payload = L.wire_bytes(padded, cfg.bits) + 4 * (padded // cfg.bucket)
    assert wire_bytes_butterfly(n, 8, cfg) == 3 * payload
    assert wire_bytes_allgather(n, 8, cfg) == 7 * payload
    assert wire_bytes_butterfly(n, 1, cfg) == 0
    assert wire_bytes_allgather(n, 1, cfg) == 0
    # q=256 doubles the per-coordinate bits
    cfg8 = QSyncConfig(q=256, bucket=4096)
    assert (wire_bytes_butterfly(n, 8, cfg8) >
            1.9 * wire_bytes_butterfly(n, 8, cfg))


def test_qsync_config_validation():
    with pytest.raises(ValueError):
        QSyncConfig(q=1)
    with pytest.raises(ValueError):
        QSyncConfig(bucket=48)            # not a power of two
    assert QSyncConfig(q=16).bits == 4
    assert QSyncConfig(q=256).bits == 8


def _world1(fn, x, y_b, cfg):
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
             check_vma=False)
    def f(xl):
        out, aux = fn(xl, y_b, jax.random.PRNGKey(7), "data", cfg)
        return out, aux.fails

    return jax.jit(f)(x)


@pytest.mark.parametrize("fn", [allgather_allreduce_mean,
                                butterfly_allreduce_mean,
                                rh_reduce_scatter_mean])
def test_world1_collectives_are_near_identity(fn):
    """world==1: the 'mean' is the vector itself; butterfly/rh skip all
    rounds, the star path round-trips one lattice encode (error <= s/2)."""
    cfg = QSyncConfig(q=16, bucket=256)
    n = 512
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    y = 1.0
    y_b = jnp.full((n // cfg.bucket,), y)
    out, fails = _world1(fn, x, y_b, cfg)
    s = 2 * y / (cfg.q - 1)
    assert out.shape == (n,)
    assert float(jnp.max(jnp.abs(out - x))) <= 0.5 * s + 1e-6
    assert float(fails) == 0.0


def test_fsdp_gather_forward_and_grad_world1():
    """dp=1 gather: forward is a bf16 cast, backward 'lq' is exact (no
    quantization rounds), and telemetry arrives as the tele cotangent."""
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = FSDPConfig(axes=("data",), qcfg=QSyncConfig(q=16, bucket=64),
                     sync="lq")
    gather = make_fsdp_gather(cfg)
    w = jax.random.normal(jax.random.PRNGKey(0), (128,))
    coef = jax.random.normal(jax.random.PRNGKey(1), (128,))

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(), P()),
             out_specs=(P(), P(), P()), check_vma=False)
    def f(w, tele):
        def loss(w, tele):
            bundle = {"w": w, "y": jnp.float32(1.0),
                      "key": jax.random.PRNGKey(3), "tele": tele}
            full = gather(bundle)
            return jnp.sum(full.astype(jnp.float32) * coef)

        l, (gw, gt) = jax.value_and_grad(loss, argnums=(0, 1))(w, tele)
        return l, gw, gt

    tele0 = jnp.zeros((TELE_WIDTH,), jnp.float32)
    l, gw, gt = jax.jit(f)(w, tele0)
    np.testing.assert_allclose(np.asarray(l),
                               float(jnp.sum(w.astype(jnp.bfloat16)
                                             .astype(jnp.float32) * coef)),
                               rtol=1e-6)
    # dp=1 lq reduce-scatter has zero rounds: gradient is exact
    np.testing.assert_allclose(np.asarray(gw), np.asarray(coef), rtol=1e-2,
                               atol=1e-3)
    assert gt.shape == (TELE_WIDTH,)
    assert float(gt[1]) == 0.0            # no decode failures


def test_effective_bucket_matches_sharding_rule():
    """fsdp picks a reduce-scatter bucket that tiles whatever padding
    models/sharding.effective_bucket chose for small leaves."""
    from repro.dist.fsdp import _effective_bucket
    from repro.models.sharding import ShardCtx, effective_bucket
    for n in (7, 32, 100, 1000, 5000):
        for dp in (1, 2, 8):
            qcfg = QSyncConfig(q=16, bucket=512)
            ctx = ShardCtx(dp=dp, qcfg=qcfg)
            b_store = effective_bucket(n, ctx)
            m = pad_to_shardable(n, dp, b_store)
            b_rs = _effective_bucket(qcfg, m, dp)
            assert m % (dp * b_rs) == 0, (n, dp, b_store, b_rs, m)
