"""Loop-trip-expanded HLO accounting (launch/hlo_analysis.py)."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def _scan_flops(L, n=128):
    def step(c, _):
        return jnp.tanh(c @ c), None
    def g(x):
        return jax.lax.scan(step, x, None, length=L)[0]
    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    return analyze(comp.as_text()).dot_flops


def test_scan_flops_scale_with_trip_count():
    f2, f16 = _scan_flops(2), _scan_flops(16)
    assert abs(f16 / f2 - 8.0) < 0.2


def test_exact_matmul_flops():
    n, L = 128, 4
    assert _scan_flops(L, n) == 2 * n**3 * L


def test_nested_scan():
    def inner(c, _):
        return c @ c, None
    def outer(c, _):
        return jax.lax.scan(inner, c, None, length=3)[0], None
    def g(x):
        return jax.lax.scan(outer, x, None, length=5)[0]
    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    flops = analyze(comp.as_text()).dot_flops
    assert flops == 2 * 64**3 * 15
