"""Serving-path tests: kv-quant decode, prefill/decode consistency, data."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.models.sharding import ShardCtx
from repro.models import transformer as T, serve as SV
from repro.train.data import DataConfig, batch_at, local_batch_at


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_int8_kv_cache_dequantizes_close_to_bf16():
    """Feed a FIXED token sequence through both decode variants and compare
    the dequantized int8 cache against the bf16 cache (token-level greedy
    comparison is meaningless on untrained weights: logits are near-ties)."""
    cfg = registry.smoke_config("qwen3-32b")
    ctx = ShardCtx(tp=1, dp=1)
    mesh = _mesh()
    params = T.init_params(cfg, ctx, jax.random.PRNGKey(0))
    feeds = jax.random.randint(jax.random.PRNGKey(3), (6, 2, 1), 0, cfg.vocab)
    caches = {}
    for kvq in (False, True):
        cache = SV.cache_zeros(cfg, ctx, 2, 32, kv_quant=kvq)
        step = SV.make_serve_step(cfg, ctx, kv_quant=kvq)

        @partial(jax.shard_map, mesh=mesh, in_specs=(P(),) * 5,
                 out_specs=(P(), P()), check_vma=False)
        def f(p, c, t, pos, k):
            return step(p, c, t, pos, k)

        f = jax.jit(f)
        for t in range(6):
            _, cache = f(params, cache, feeds[t], jnp.int32(t),
                         jax.random.PRNGKey(1))
        caches[kvq] = cache
    kb = np.asarray(caches[False]["k"].astype(jnp.float32))[:, :, :, :6]
    scale = np.asarray(caches[True]["k_scale"]) / 127.0     # (L, B, kv, S)
    kq = (np.asarray(caches[True]["k"]).astype(np.float32)
          * scale[:, :, :, :, None])[:, :, :, :6]
    denom = np.maximum(np.abs(kb).max(), 1e-6)
    assert np.max(np.abs(kb - kq)) / denom < 0.02, (
        np.max(np.abs(kb - kq)), denom)


def test_prefill_then_decode_consistent_with_pure_decode():
    """Cache built by prefill(tokens) == cache built token-by-token: the
    next greedy token must match."""
    cfg = registry.smoke_config("glm4-9b")
    ctx = ShardCtx(tp=1, dp=1)
    mesh = _mesh()
    params = T.init_params(cfg, ctx, jax.random.PRNGKey(0))
    B, S_max, Sp = 2, 32, 8
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, Sp), 0, cfg.vocab)
    step = SV.make_serve_step(cfg, ctx)
    pf = SV.make_prefill(cfg, ctx)

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(),) * 5,
             out_specs=(P(), P()), check_vma=False)
    def fstep(p, c, t, pos, k):
        return step(p, c, t, pos, k)

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(),) * 3,
             out_specs=(P(), P()), check_vma=False)
    def fpre(p, t, k):
        return pf(p, t, k)

    key = jax.random.PRNGKey(9)
    # path A: token-by-token through the decode step
    cache = SV.cache_zeros(cfg, ctx, B, S_max)
    nxt = None
    for t in range(Sp):
        nxt, cache = jax.jit(fstep)(params, cache, prompt[:, t:t + 1],
                                    jnp.int32(t), key)
    a = np.asarray(nxt)

    # path B: prefill writes the cache in one shot
    last, pcache = jax.jit(fpre)(params, prompt, key)
    cache_b = SV.cache_zeros(cfg, ctx, B, S_max)
    # place prefill kv into the [0, Sp) region of the decode cache
    k_new = jnp.zeros_like(cache_b["k"]).at[:, :, :, :Sp].set(pcache["k"])
    v_new = jnp.zeros_like(cache_b["v"]).at[:, :, :, :Sp].set(pcache["v"])
    cache_b = {"k": k_new, "v": v_new}
    # decode the token after the prompt with BOTH caches; must agree
    nxt_a, _ = jax.jit(fstep)(params, cache, prompt[:, -1:],
                              jnp.int32(Sp), key)
    nxt_b, _ = jax.jit(fstep)(params, cache_b, prompt[:, -1:],
                              jnp.int32(Sp), key)
    assert np.array_equal(np.asarray(nxt_a), np.asarray(nxt_b))


def test_data_pipeline_deterministic_and_shardable():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=3)
    a = batch_at(cfg, 5)
    b = batch_at(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at(cfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # per-host slices tile the global batch exactly
    parts = [local_batch_at(cfg, 5, r, 4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), a["tokens"])


def test_rotated_collectives_roundtrip():
    """QSyncConfig(rotate=True): the RLQ bucket rotation must be inverted
    exactly by the mean path (single device => mean == identity-ish)."""
    from repro.dist.collectives import QSyncConfig, _bucketize, _unbucketize
    cfg = QSyncConfig(q=16, bucket=256, rotate=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
    b = _bucketize(x, cfg)
    back = _unbucketize(b, 1024, cfg)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-4,
                               atol=1e-5)
