"""End-to-end trainer: loss decreases; checkpoint/restart fault tolerance."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx
from repro.train.trainer import Trainer, TrainConfig
from repro.train.optim import OptConfig
from repro.train.data import DataConfig
from repro.dist.collectives import QSyncConfig


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _cfg():
    return ModelConfig(arch="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=128)


def _trainer(tmp, steps, hook=None):
    tc = TrainConfig(steps=steps, ckpt_every=10, ckpt_dir=str(tmp),
                     log_every=1000)
    return Trainer(_cfg(),
                   ShardCtx(tp=1, dp=1, qcfg=QSyncConfig(q=16, bucket=128),
                            grad_sync="lq"),
                   _mesh(), OptConfig(lr=1e-2, warmup=5, decay_steps=100),
                   tc, DataConfig(vocab=128, seq_len=32, global_batch=8),
                   failure_hook=hook)


@pytest.mark.slow
def test_loss_decreases_and_restart(tmp_path):
    tr = _trainer(tmp_path, 25)
    tr.tc = tr.tc  # noqa
    state = tr.train()
    assert int(state["step"]) == 25

    armed = {"on": True}

    def hook(step):
        if step == 27 and armed["on"]:
            armed["on"] = False
            raise RuntimeError("injected failure")

    tr2 = _trainer(tmp_path, 35, hook=hook)
    state2 = tr2.train()
    assert int(state2["step"]) == 35   # survived the injected failure
