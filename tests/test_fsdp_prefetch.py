"""Split (async/wait) FSDP gather vs the monolithic gather, the sharded
anchor layout, and the prefetch-pipelined trainer.

The split gather powering the double-buffered layer scan must be *bitwise*
equivalent to the monolithic custom-vjp gather — same forward values, same
w-cotangents, same tele cotangents — across packed/unpacked wire paths,
multi-axis DP, and all three anchor modes (off / legacy replicated /
sharded).  Multi-device cases run in subprocesses (XLA_FLAGS must be set
before jax initializes), like tests/test_multidevice.py.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import fsdp as F
from repro.dist.collectives import QSyncConfig

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_8dev(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# Accounting: the sharded anchor changes *state* bytes, never *sync* bytes
# ---------------------------------------------------------------------------

def test_wire_bytes_bwd_ignores_anchor_layout():
    """The quantized sync moves the same wire bytes whether the anchor is
    sharded or replicated — the layout only changes what each rank stores
    (anchor_bytes_step) and what the forward gather rebuilds
    (anchor_gather_bytes_fwd)."""
    qc = QSyncConfig(q=16, bucket=512)
    m = 8 * 4096
    for anchored in (False, True):
        a = F.FSDPConfig(axes=("data",), qcfg=qc, sync="lq",
                         anchored=anchored, anchor_sharded=True)
        b = dataclasses.replace(a, anchor_sharded=False)
        assert F.wire_bytes_bwd(m, [8], a) == F.wire_bytes_bwd(m, [8], b)

    sharded = F.FSDPConfig(axes=("data",), qcfg=qc, sync="lq", anchored=True,
                           anchor_sharded=True)
    legacy = dataclasses.replace(sharded, anchor_sharded=False)
    # per-step anchor state beyond each rank's own shard
    assert F.anchor_bytes_step(m, [8], sharded) == 0
    assert F.anchor_bytes_step(m, [8], legacy) == 4 * (m - m // 8)
    # the sharded anchor is instead rebuilt by the forward gather (f32)
    assert F.anchor_gather_bytes_fwd(m, [8], sharded) == 4 * (m - m // 8)
    assert F.anchor_gather_bytes_fwd(m, [8], legacy) == 0
    # neither exists unanchored
    off = dataclasses.replace(sharded, anchored=False)
    assert F.anchor_bytes_step(m, [8], off) == 0
    assert F.anchor_gather_bytes_fwd(m, [8], off) == 0


# ---------------------------------------------------------------------------
# world=1: split == monolithic, bitwise, in-process
# ---------------------------------------------------------------------------

def test_split_gather_bitwise_world1():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = F.FSDPConfig(axes=("data",), qcfg=QSyncConfig(q=16, bucket=64),
                       sync="lq")
    gather = F.make_fsdp_gather(cfg)
    g_async, g_wait = F.make_fsdp_gather_split(cfg)
    w = jax.random.normal(jax.random.PRNGKey(0), (128,))
    coef = jax.random.normal(jax.random.PRNGKey(1), (128,))
    tele0 = jnp.zeros((F.TELE_WIDTH,), jnp.float32)

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(), P()),
             out_specs=(P(),) * 6, check_vma=False)
    def f(w, tele):
        def bundle(t):
            return {"w": w, "y": jnp.float32(1.0),
                    "key": jax.random.PRNGKey(3), "tele": t}

        def loss_mono(w_, t):
            return jnp.sum(gather(bundle(t)).astype(jnp.float32) * coef)

        def loss_split(w_, t):
            return jnp.sum(g_wait(g_async(bundle(t)))
                           .astype(jnp.float32) * coef)

        lm, (gwm, gtm) = jax.value_and_grad(loss_mono, (0, 1))(w, tele)
        ls, (gws, gts) = jax.value_and_grad(loss_split, (0, 1))(w, tele)
        return lm, gwm, gtm, ls, gws, gts

    lm, gwm, gtm, ls, gws, gts = jax.jit(f)(w, tele0)
    assert np.asarray(lm).tobytes() == np.asarray(ls).tobytes()
    assert np.asarray(gwm).tobytes() == np.asarray(gws).tobytes()
    assert np.asarray(gtm).tobytes() == np.asarray(gts).tobytes()


# ---------------------------------------------------------------------------
# 8 devices, multi-axis DP, packed + unpacked, all three anchor modes
# ---------------------------------------------------------------------------

def test_split_gather_parity_8dev():
    """Split async/wait gather is bitwise-identical to the monolithic
    gather on a (2,4) pod x data mesh, packed and unpacked, unanchored and
    anchored; the sharded anchor produces bitwise the same mean as the
    legacy replicated anchor while each rank carries only its (m/8,) slice
    — the in-test len() cross-check of fsdp.anchor_bytes_step == 0."""
    out = _run_8dev("""
        from functools import partial
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import QSyncConfig
        from repro.dist.fsdp import (FSDPConfig, TELE_WIDTH, leaf_nb,
                                     make_fsdp_gather, make_fsdp_gather_split,
                                     tele_width, anchor_bytes_step)
        import dataclasses
        mesh = jax.make_mesh((2, 4), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        m = 8 * 512
        shard = m // 8
        w = jax.random.normal(jax.random.PRNGKey(0), (8, shard))
        anchor_full = jax.random.normal(jax.random.PRNGKey(2), (m,))
        anchor_sh = anchor_full.reshape(8, shard)

        def run(cfg, split, y, tele_w, anchor=None, anchor_spec=P()):
            gather = make_fsdp_gather(cfg)
            g_async, g_wait = make_fsdp_gather_split(cfg)
            anchored = anchor is not None
            coef = jax.random.normal(jax.random.PRNGKey(1), (m,)) + 10.0
            specs = (P(("pod", "data")), P(), anchor_spec)
            @partial(jax.shard_map, mesh=mesh, in_specs=specs,
                     out_specs=(P(("pod", "data")),) * 3, check_vma=False)
            def f(wl, tele, anc):
                def loss(wv, t):
                    yv = ({"y": y, "anchor": anc.reshape(-1)}
                          if anchored else y)
                    bundle = {"w": wv.reshape(-1), "y": yv,
                              "key": jax.random.PRNGKey(3), "tele": t}
                    full = (g_wait(g_async(bundle)) if split
                            else gather(bundle))
                    return jnp.sum(full.astype(jnp.float32) * coef)
                l, (gw, gt) = jax.value_and_grad(loss, (0, 1))(wl, tele)
                return (jnp.broadcast_to(l, (1,)), gw.reshape(1, -1),
                        gt[None])
            anc_in = anchor if anchor is not None else jnp.zeros((8, 1))
            l, gw, gt = jax.jit(f)(w, jnp.zeros((tele_w,)), anc_in)
            return (np.asarray(l), np.asarray(gw), np.asarray(gt))

        for packed in (False, True):
            qc = QSyncConfig(q=16, bucket=64, packed=packed)
            nb = leaf_nb(m, 8, qc)
            y_b = jnp.full((nb,), 1.0)
            # --- unanchored, multi-axis rh ---
            cfg = FSDPConfig(axes=("pod", "data"), qcfg=qc, sync="lq")
            mono = run(cfg, False, y_b, tele_width(nb))
            splt = run(cfg, True, y_b, tele_width(nb))
            for a, b in zip(mono, splt):
                assert a.tobytes() == b.tobytes(), "unanchored split parity"
            # --- anchored, legacy replicated anchor ---
            cfg_l = FSDPConfig(axes=("pod", "data"), qcfg=qc, sync="lq",
                               anchored=True, anchor_sharded=False)
            ml = run(cfg_l, False, y_b, tele_width(nb, m, True),
                     anchor=jnp.broadcast_to(anchor_full, (8, m)),
                     anchor_spec=P(("pod", "data")))
            sl = run(cfg_l, True, y_b, tele_width(nb, m, True),
                     anchor=jnp.broadcast_to(anchor_full, (8, m)),
                     anchor_spec=P(("pod", "data")))
            for a, b in zip(ml, sl):
                assert a.tobytes() == b.tobytes(), "legacy split parity"
            # --- anchored, sharded anchor (each rank holds its slice) ---
            cfg_s = dataclasses.replace(cfg_l, anchor_sharded=True)
            ms = run(cfg_s, False, y_b, tele_width(nb, shard, True),
                     anchor=anchor_sh, anchor_spec=P(("pod", "data")))
            ss = run(cfg_s, True, y_b, tele_width(nb, shard, True),
                     anchor=anchor_sh, anchor_spec=P(("pod", "data")))
            for a, b in zip(ms, ss):
                assert a.tobytes() == b.tobytes(), "sharded split parity"
            # sharded vs legacy: identical loss and mean (the gathered
            # anchor reassembles the exact replicated values)
            assert ms[0].tobytes() == ml[0].tobytes()
            assert ms[1].tobytes() == ml[1].tobytes()
            # tele cotangents agree on everything but the carried anchor
            lo = TELE_WIDTH + 2 * nb
            assert ms[2][:, :lo].tobytes() == ml[2][:, :lo].tobytes()
            # the carried anchor payload: legacy re-materializes the full
            # (m,) mean on every rank, sharded carries only this rank's
            # (m/8,) slice — and those slices tile the legacy vector
            a_leg, a_sh = ml[2][:, lo:], ms[2][:, lo:]
            assert a_leg.shape[1] == m and a_sh.shape[1] == shard
            for r in range(8):
                assert a_sh[r].tobytes() == \\
                    a_leg[r, r * shard:(r + 1) * shard].tobytes()
            # len() cross-check of the accounting: extra carried state
            # beyond the rank's own shard matches anchor_bytes_step
            assert 4 * (a_sh.shape[1] - shard) == \\
                anchor_bytes_step(m, [2, 4], cfg_s) == 0
            assert 4 * (a_leg.shape[1] - shard) == \\
                anchor_bytes_step(m, [2, 4], cfg_l)
        print("SPLIT_PARITY_OK")
    """)
    assert "SPLIT_PARITY_OK" in out


# ---------------------------------------------------------------------------
# trainer: prefetched scan bit-identical to serial (8 devices, slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_prefetch_trainer_bit_identity_8dev():
    """3 steps of the tiny anchored trainer, serial vs double-buffered
    prefetch: bitwise-identical losses and final params, strictly lower
    HLO collective_exposed_fraction, zero sharded-anchor state bytes.
    Delegates to the CI smoke (benchmarks/fsdp_overlap_probe.py)."""
    probe = os.path.join(_ROOT, "benchmarks", "fsdp_overlap_probe.py")
    r = subprocess.run([sys.executable, probe, "--check"],
                       capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "FSDP_OVERLAP_OK" in r.stdout
