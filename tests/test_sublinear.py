"""Sublinear-communication scheme (paper §7): exact small-d implementation."""
import numpy as np
import pytest

from repro.core.sublinear import (SublinearLattice, simulated_variance,
                                  vqsgd_cross_polytope_variance)


def test_error_bounded_by_3eps():
    rng = np.random.default_rng(0)
    sub = SublinearLattice(s=0.5, q=1.5, d=4)
    for _ in range(60):
        x = rng.normal(size=4) * 5
        xv = x + rng.normal(size=4) * 0.05
        p = sub.encode(x, rng)
        z = sub.decode(p, xv)
        assert np.linalg.norm(z - x) <= 3 * sub.eps + 1e-9


def test_unbiased():
    rng = np.random.default_rng(1)
    sub = SublinearLattice(s=0.4, q=1.5, d=3)
    x = np.array([0.3, -1.2, 2.7])
    zs = [sub.decode(sub.encode(x, rng), x) for _ in range(4000)]
    dev = np.abs(np.mean(zs, axis=0) - x).max()
    assert dev < 5 * sub.s / np.sqrt(12 * 4000) * 3


def test_bits_sublinear_in_regime():
    sub = SublinearLattice(s=1.0, q=0.25, d=64)
    assert sub.bits() < 64 * 2      # < 2 bits/coord


def test_simulated_variance_monotonic_in_bits():
    v1 = simulated_variance(256, 1.0, 0.5)
    v2 = simulated_variance(256, 1.0, 1.0)
    v3 = simulated_variance(256, 1.0, 2.0)
    assert v1 > v2 > v3


def test_vqsgd_comparison_scaling():
    assert vqsgd_cross_polytope_variance(256, 1.0, 8) == pytest.approx(32.0)
