"""repro.core.qstate + bucketing: the anchored-quantization state layer.

Covers the ISSUE 4 tentpole invariants below the collectives:
  * QState(anchor=0 / None, uniform y) is bit-identical to the historical
    anchor-free kernel path (encode, decode, batched decode);
  * the fused in-kernel anchor subtract matches the jnp oracle bitwise and
    keeps integer coordinates ~y/s-sized in the large-norm regime;
  * core.bucketing is the single bucket-layout definition: the collectives'
    and the agg protocol's bucketizers are the same function (the
    server-vs-star bit-parity acceptance depends on this);
  * update_y's per-bucket escalate/relax dynamics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import rounds
from repro.agg.transport import frame as wire
from repro.core import bucketing as B
from repro.core import qstate as QS
from repro.core.qstate import QState
from repro.dist.collectives import (QSyncConfig, _bucketize, _unbucketize,
                                    allgather_allreduce_mean,
                                    butterfly_allreduce_mean,
                                    rh_reduce_scatter_mean)
from repro.kernels import ops as K
from repro.kernels import ref


# ---------------------------------------------------------------------------
# QState basics + update dynamics
# ---------------------------------------------------------------------------

def test_as_qstate_promotes_bare_y():
    y = jnp.full((4,), 2.0)
    qs = QS.as_qstate(y)
    assert isinstance(qs, QState) and qs.anchor is None
    np.testing.assert_array_equal(np.asarray(qs.y), np.asarray(y))
    qs2 = QS.as_qstate(qs)
    assert qs2 is qs


def test_update_y_escalates_failed_buckets_only():
    y = jnp.full((6,), 1.0)
    fails = jnp.array([0.0, 2.0, 0.0, 0.0, 1.0, 0.0])
    dist = jnp.full((6,), 0.3)
    y2 = np.asarray(QS.update_y(y, fails, dist, decay=0.5, escalate=2.0))
    assert y2[1] == 2.0 and y2[4] == 2.0          # escalated
    clean = [0, 2, 3, 5]
    # clean buckets relax toward 2.5 * dist = 0.75
    np.testing.assert_allclose(y2[clean], 0.5 * 1.0 + 0.5 * 0.75)


def test_update_y_shrinks_as_inputs_concentrate():
    y = jnp.full((4,), 1.0)
    zeros = jnp.zeros((4,))
    for _ in range(20):
        y = QS.update_y(y, zeros, jnp.full((4,), 0.01), decay=0.5)
    # equilibrium: y* = 2.5 * dist once dist dominates the clip
    np.testing.assert_allclose(np.asarray(y), 0.025, rtol=0.3)


def test_update_y_zero_dist_is_identity():
    y = jnp.array([0.5, 2.0])
    y2 = QS.update_y(y, jnp.zeros(2), jnp.zeros(2))
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y), rtol=1e-6)


# ---------------------------------------------------------------------------
# One bucket-layout definition (satellite: dedup _bucketize/unbucketize)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rotate", [False, True])
@pytest.mark.parametrize("n", [1000, 4096])
def test_bucketize_single_definition(rotate, n):
    """collectives._bucketize, agg.rounds.bucketize and core.bucketing
    produce bit-identical buckets for the same (vector, diag) — the
    server-vs-star acceptance test rests on this."""
    bucket = 256
    cfg = QSyncConfig(q=16, bucket=bucket, rotate=rotate)
    spec = wire.RoundSpec(round_id=1, d=n, cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    via_collectives = _bucketize(x, cfg)
    via_agg = rounds.bucketize(x, spec)
    diag = rounds.rotation_diag(spec) if rotate else None
    via_core = B.bucketize(x, bucket, diag=diag, use_kernel=cfg.packed)
    np.testing.assert_array_equal(np.asarray(via_collectives),
                                  np.asarray(via_agg))
    np.testing.assert_array_equal(np.asarray(via_collectives),
                                  np.asarray(via_core))
    back = _unbucketize(via_collectives, n, cfg)
    back2 = rounds.unbucketize(via_agg, spec)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(back2))


# ---------------------------------------------------------------------------
# Fused anchor in the kernels: zero-anchor bit-parity + oracle parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,q", [(5000, 16), (4096, 256)])
def test_zero_anchor_is_bit_identical(n, q):
    x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 5
    a = x + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (n,))
    u = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=-0.5,
                           maxval=0.5)
    s = jnp.full((n,), 0.05)
    zeros = jnp.zeros((n,))
    w_none = K.lattice_encode(x, u, s, q=q)
    w_zero = K.lattice_encode(x, u, s, q=q, anchor=zeros)
    np.testing.assert_array_equal(np.asarray(w_none), np.asarray(w_zero))
    for mode in ("coords", "point"):
        k_none = K.lattice_decode(w_none, a, u, s, q=q, mode=mode)
        k_zero = K.lattice_decode(w_none, a, u, s, q=q, mode=mode, ref=zeros)
        np.testing.assert_array_equal(np.asarray(k_none), np.asarray(k_zero))
    words2 = jnp.stack([w_none, w_none])
    kb_none = K.lattice_decode_batched(words2, a, u, s, q=q)
    kb_zero = K.lattice_decode_batched(words2, a, u, s, q=q, ref=zeros)
    np.testing.assert_array_equal(np.asarray(kb_none), np.asarray(kb_zero))


def test_anchored_kernel_matches_oracle_and_bounds_coords():
    """k = round((x - anchor)/s - u) fused in-kernel == the jnp oracle,
    bitwise — and |k| stays ~y/s however large |x| is (the large-norm
    regime where raw coordinates overflow the f32 mantissa)."""
    n, q = 5000, 16
    huge = 1e7
    x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.1 + huge
    a = x + 0.02 * jax.random.normal(jax.random.PRNGKey(1), (n,))
    u = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=-0.5,
                           maxval=0.5)
    s = jnp.full((n,), 0.05)
    w, k = K.lattice_encode(x, u, s, q=q, anchor=a, return_coords=True)
    wr, kr = ref.lattice_encode_ref(x, u, s, q=q, bits=4, anchor=a,
                                    return_coords=True)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(kr))
    assert int(jnp.max(jnp.abs(k))) < 64          # ~y/s, not ~|x|/s = 2e8
    kd = K.lattice_decode(w, a, u, s, q=q, mode="coords", ref=a)
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(k))
    z = K.lattice_decode(w, a, u, s, q=q, mode="point", ref=a)
    zr = ref.lattice_decode_ref(w, a, u, s, q=q, bits=4, n=n, mode="point",
                                ref=a)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(zr))
    kb = K.lattice_decode_batched(w[None], a, u, s, q=q, mode="coords",
                                  ref=a)
    np.testing.assert_array_equal(np.asarray(kb)[0], np.asarray(k))


# ---------------------------------------------------------------------------
# Collectives accept QState; zero anchor == bare y, bitwise (world 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn", [allgather_allreduce_mean,
                                butterfly_allreduce_mean,
                                rh_reduce_scatter_mean])
def test_world1_qstate_zero_anchor_matches_bare_y(fn):
    from functools import partial
    from jax.sharding import PartitionSpec as P
    cfg = QSyncConfig(q=16, bucket=256)
    n = 512
    x = jax.random.normal(jax.random.PRNGKey(3), (n,))
    y_b = jnp.full((n // 256,), 1.0)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def run(state):
        @partial(jax.shard_map, mesh=mesh, in_specs=(P(),),
                 out_specs=(P(), P()), check_vma=False)
        def f(xl):
            out, aux = fn(xl, state, jax.random.PRNGKey(7), "data", cfg)
            return out, jnp.stack([aux.fails, aux.max_dist, aux.y_next])
        return jax.jit(f)(x)

    o_bare, t_bare = run(y_b)
    o_zero, t_zero = run(QState(y=y_b, anchor=jnp.zeros((n,))))
    np.testing.assert_array_equal(np.asarray(o_bare), np.asarray(o_zero))
    np.testing.assert_array_equal(np.asarray(t_bare), np.asarray(t_zero))


def test_rh_returns_kept_segment_y():
    """world=1 rh: y_seg is the full per-bucket y (no halving rounds)."""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    cfg = QSyncConfig(q=16, bucket=128)
    n, nb = 512, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    y_b = jnp.arange(1.0, nb + 1.0)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(),),
             out_specs=(P(), P(), P(), P()), check_vma=False)
    def f(xl):
        out, aux = rh_reduce_scatter_mean(xl, y_b, jax.random.PRNGKey(7),
                                          "data", cfg)
        return out, aux.y_seg, aux.fails_b, aux.dist_b

    out, y_seg, fails_b, dist_b = jax.jit(f)(x)
    np.testing.assert_array_equal(np.asarray(y_seg), np.asarray(y_b))
    assert fails_b.shape == (nb,) and dist_b.shape == (nb,)
    assert float(jnp.sum(fails_b)) == 0.0
