# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device integration tests spawn subprocesses (tests/test_multidevice.py).
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
