"""Flash-attention kernel vs plain-softmax oracle (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("sq,sk", [(256, 256), (512, 512), (256, 1024)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(sq, sk, causal):
    if causal and sq != sk:
        pytest.skip("causal assumes aligned q/k positions")
    BH, d = 3, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (BH, sq, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, sk, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, sk, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    BH, s, d = 2, 512, 128
    q = jax.random.normal(jax.random.PRNGKey(0), (BH, s, d)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, s, d)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, s, d)).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_traffic_is_linear_not_quadratic():
    """The point of the kernel: HBM traffic O(S*D), not O(S^2)."""
    # structural check: kernel output shape bytes scale linearly in S
    BH, d = 1, 64
    for s in (256, 512):
        q = jnp.ones((BH, s, d))
        out = ops.flash_attention(q, q, q)
        assert out.shape == (BH, s, d)
