"""Per-arch smoke tests (deliverable f): reduced config, one train step on
CPU, asserting output shapes + no NaNs; plus one decode step per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.models.sharding import ShardCtx
from repro.models import transformer as T
from repro.models import encdec as ED
from repro.models import serve as SV
from repro.dist.collectives import QSyncConfig

ARCHS = list(registry.ARCHS)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _ctx():
    return ShardCtx(tp=1, dp=1, qcfg=QSyncConfig(q=16, bucket=64),
                    grad_sync="lq")


def _batch(cfg, key, B=2, S=16):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
         "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
         "mask": jnp.ones((B, S))}
    if cfg.family == "vlm":
        b["img"] = jax.random.normal(key, (B, cfg.img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.smoke_config(arch)
    ctx = _ctx()
    mesh = _mesh()
    key = jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        params = ED.init_encdec_params(cfg, ctx, key)
        loss_fn = ED.make_encdec_loss_fn(cfg, ctx)
        y = ED.encdec_y_init(cfg, ctx, 5.0)
        tele = ED.encdec_tele_zeros(cfg, ctx)
    else:
        params = T.init_params(cfg, ctx, key)
        loss_fn = T.make_loss_fn(cfg, ctx)
        y = T.y_init(cfg, ctx, 5.0)
        tele = T.tele_zeros(cfg, ctx)
    batch = _batch(cfg, key)

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(),) * 5,
             out_specs=(P(), P()), check_vma=False)
    def step(params, tele, batch, key, y):
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, tele, batch, key, y)
        gn = sum(jnp.sum(x.astype(jnp.float32) ** 2)
                 for x in jax.tree.leaves(g))
        return m["loss"], gn

    loss, gn = jax.jit(step)(params, tele, batch, key, y)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={float(loss)}"
    assert float(loss) < np.log(cfg.vocab) + 1.0
    assert np.isfinite(float(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = registry.smoke_config(arch)
    ctx = _ctx()
    mesh = _mesh()
    key = jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        params = ED.init_encdec_params(cfg, ctx, key)
    else:
        params = T.init_params(cfg, ctx, key)
    B, S_max = 2, 32
    cache = SV.cache_zeros(cfg, ctx, B, S_max)
    step = SV.make_serve_step(cfg, ctx)

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(),) * 5,
             out_specs=(P(), P()), check_vma=False)
    def f(params, cache, tokens, pos, key):
        return step(params, cache, tokens, pos, key)

    toks = jnp.array([[1], [2]], jnp.int32)
    nxt, cache2 = jax.jit(f)(params, cache, toks, jnp.int32(0), key)
    assert nxt.shape == (B,)
    assert int(jnp.max(nxt)) < cfg.vocab + ctx.tp  # vocab padding slack
    for k, v in cache2.items():
        assert not bool(jnp.any(jnp.isnan(v.astype(jnp.float32)))), (arch, k)


def test_ssd_matches_naive_recurrence():
    """Mamba-2 chunked SSD == step-by-step recurrence."""
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    b, s, h, p, n = 2, 24, 3, 8, 16
    key = jax.random.PRNGKey(0)
    xh = jax.random.normal(key, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (b, s, n)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(4), (b, s, n)) * 0.3
    y_chunk, final = ssd_chunked(xh, dt, A, Bm, Cm, chunk=8)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, state = ssd_decode_step(xh[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                                    state)
        ys.append(yt)
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_naive, np.float32),
                               rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               rtol=2e-2, atol=2e-3)


def test_rglru_assoc_scan_matches_loop():
    from repro.models.rglru import rg_lru
    b, s, c = 2, 17, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, c))
    wts = {"w_r": jnp.ones((c,)) * 0.3, "b_r": jnp.zeros((c,)),
           "w_i": jnp.ones((c,)) * 0.2, "b_i": jnp.zeros((c,)),
           "lam": jnp.ones((c,))}
    y, last = rg_lru(x, wts)
    # naive loop
    h = jnp.zeros((b, c))
    outs = []
    for t in range(s):
        xt = x[:, t].astype(jnp.float32)
        r = jax.nn.sigmoid(xt * wts["w_r"] + wts["b_r"])
        i = jax.nn.sigmoid(xt * wts["w_i"] + wts["b_i"])
        log_a = -8.0 * jax.nn.softplus(wts["lam"]) * r
        a = jnp.exp(log_a)
        h = a * h + jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (i * xt)
        outs.append(h)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(jnp.stack(outs, 1), np.float32),
                               rtol=1e-4, atol=1e-5)


def test_y_init_seeds_from_rotated_bound():
    """With qcfg.rotate the per-leaf y seeds come from the §6 rotated-space
    bound (value * sqrt(2 ln(2b/beta)) for bucket size b) instead of the
    raw-space guess; without rotation they stay the raw guess."""
    import math
    from repro.models.config import ModelConfig
    from repro.models.sharding import effective_bucket, leaf_y0
    cfg = ModelConfig(arch="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=256)
    ctx_raw = _ctx()
    ctx_rot = ShardCtx(tp=1, dp=1, grad_sync="lq",
                       qcfg=QSyncConfig(q=16, bucket=64, rotate=True))
    metas = T.all_metas(cfg, ctx_rot)
    y_raw = T.y_init(cfg, ctx_raw, 1.0)
    y_rot = T.y_init(cfg, ctx_rot, 1.0)
    for k, m in metas["layers"].items():
        # per-bucket state seeds uniformly from the leaf bound
        assert np.all(np.asarray(y_raw["layers"][k]) == 1.0)
        b = effective_bucket(m.numel(), ctx_rot)
        want = math.sqrt(b) * math.sqrt(2 * math.log(2 * b / 1e-3) / b)
        np.testing.assert_allclose(float(y_rot["layers"][k][0, 0]), want,
                                   rtol=1e-6)
        np.testing.assert_allclose(float(y_rot["layers"][k][0, 0]),
                                   leaf_y0(m, ctx_rot, 1.0), rtol=1e-6)
        assert np.all(np.asarray(y_rot["layers"][k])
                      == np.asarray(y_rot["layers"][k])[0, 0])
    # scales linearly with the raw guess
    y2 = T.y_init(cfg, ctx_rot, 2.0)
    k0 = sorted(metas["layers"])[0]
    np.testing.assert_allclose(2 * float(y_rot["layers"][k0][0, 0]),
                               float(y2["layers"][k0][0, 0]), rtol=1e-6)
