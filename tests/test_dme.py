"""Paper algorithm tests: MeanEstimation / VarianceReduction (§4, Thms 2/16/17).

The headline claim: output error depends on input *variance* (pairwise
distance y), NOT input norm — verified by placing inputs far from the origin.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeQ, RotatedLatticeQ, QSGD, CompressorCtx,
                        mean_estimation_star, mean_estimation_tree,
                        butterfly_mean, variance_reduction)
from repro.core import rotation as R


def _inputs(n=8, d=256, norm=1000.0, spread=0.1, seed=0):
    mu = jax.random.normal(jax.random.PRNGKey(seed), (d,)) * norm
    xs = mu + spread * jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    y = float(2 * jnp.max(jnp.abs(xs - xs.mean(0))))
    return xs, y


def test_star_all_outputs_equal_and_unbiasedish():
    xs, y = _inputs()
    comp = LatticeQ(q=16)
    res = mean_estimation_star(xs, y, comp, jax.random.PRNGKey(2),
                               CompressorCtx(y=y))
    assert bool(res.decode_ok)
    err = float(jnp.max(jnp.abs(res.est[0] - xs.mean(0))))
    s = 2 * y / 15
    assert err < 2 * s, f"error {err} should be within ~2 lattice cells {s}"


def test_error_independent_of_input_norm():
    """The paper's core claim: error tracks y, not ||x||."""
    errs = []
    for norm in (1.0, 1e3, 1e6):
        xs, y = _inputs(norm=norm)
        comp = LatticeQ(q=16)
        res = mean_estimation_star(xs, y, comp, jax.random.PRNGKey(2),
                                   CompressorCtx(y=y))
        errs.append(float(jnp.max(jnp.abs(res.est[0] - xs.mean(0)))))
    assert max(errs) < 4 * min(max(errs[0], 1e-6), 1.0) + 0.2, errs
    # norm grew 1e6x; error must not grow with it
    assert errs[2] < 10 * (errs[0] + 1e-3), errs


def test_variance_scales_inverse_q():
    """Theorem 2/16: variance O(y^2/q) -> per-coord error ~ s = 2y/(q-1)."""
    xs, y = _inputs(n=4, d=512)
    out = {}
    for q in (4, 16, 64):
        comp = LatticeQ(q=q)
        trials = []
        for t in range(6):
            res = mean_estimation_star(xs, y, comp, jax.random.PRNGKey(10 + t),
                                       CompressorCtx(y=y))
            trials.append(float(jnp.mean((res.est[0] - xs.mean(0)) ** 2)))
        out[q] = np.mean(trials)
    # quadrupling q (doubling bits) should cut MSE by ~16x; demand >4x
    assert out[4] / out[16] > 4, out
    assert out[16] / out[64] > 4, out


def test_tree_matches_star_quality():
    xs, y = _inputs(n=8)
    res = mean_estimation_tree(xs, y, m=8, key=jax.random.PRNGKey(3))
    assert bool(res.decode_ok)
    err = float(jnp.linalg.norm(res.est[0] - xs.mean(0)))
    assert err < 0.5


def test_butterfly_identical_outputs():
    xs, y = _inputs(n=8)
    res = butterfly_mean(xs, y, LatticeQ(q=16), jax.random.PRNGKey(4),
                         CompressorCtx(y=y))
    assert bool(res.decode_ok), "all machines must hold the same output"


def test_variance_reduction_reduces_variance():
    """VR: averaging n noisy estimates + quantization still reduces variance
    below a single input's variance (the paper's motivating property)."""
    d, n, sigma = 256, 16, 1.0
    nabla = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 100
    mses_in, mses_out = [], []
    for t in range(8):
        xs = nabla + sigma / np.sqrt(d) * jax.random.normal(
            jax.random.PRNGKey(100 + t), (n, d)) * np.sqrt(d)
        res = variance_reduction(xs, sigma, LatticeQ(q=64),
                                 jax.random.PRNGKey(200 + t), alpha=4.0)
        mses_in.append(float(jnp.sum((xs[0] - nabla) ** 2)))
        mses_out.append(float(jnp.sum((res.est[0] - nabla) ** 2)))
    assert np.mean(mses_out) < 0.5 * np.mean(mses_in), (
        np.mean(mses_in), np.mean(mses_out))


def test_rlq_beats_norm_based_on_uncentered_inputs():
    """Paper Exp 2 (Figures 3-4): LQ/RLQ variance < QSGD when inputs are far
    from the origin, at comparable bit budgets."""
    xs, y = _inputs(n=2, d=1024, norm=100.0, spread=0.05)
    diag = R.rotation_keypair(jax.random.PRNGKey(7), 1024)
    yr = float(2 * jnp.max(jnp.abs(R.rotate(xs - xs.mean(0), diag)))) + 1e-6

    def mse(comp, ctx):
        es = []
        for t in range(5):
            z = comp.roundtrip(xs[0], ctx, jax.random.PRNGKey(300 + t),
                               anchor=xs[1])
            es.append(float(jnp.sum((z - xs[0]) ** 2)))
        return np.mean(es)

    m_lq = mse(LatticeQ(q=8), CompressorCtx(y=y))
    m_rlq = mse(RotatedLatticeQ(q=8), CompressorCtx(y=yr, diag=diag))
    m_qsgd = mse(QSGD(qlevel=8), CompressorCtx())
    assert m_lq < m_qsgd / 10, (m_lq, m_qsgd)
    assert m_rlq < m_qsgd / 10, (m_rlq, m_qsgd)
