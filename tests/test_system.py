"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeQ, CompressorCtx, mean_estimation_star)


def test_quantized_distributed_sgd_converges_least_squares():
    """Paper Exp 3 in miniature: 2-worker quantized-gradient GD on least
    squares converges close to the unquantized trajectory."""
    d, S = 20, 512
    key = jax.random.PRNGKey(0)
    w_star = jax.random.normal(key, (d,))
    A = jax.random.normal(jax.random.PRNGKey(1), (S, d))
    b = A @ w_star

    def grad_half(w, half):
        Ah, bh = A[half::2], b[half::2]
        return 2 * Ah.T @ (Ah @ w - bh) / Ah.shape[0]

    def run(quantized: bool):
        w = jnp.zeros((d,))
        losses = []
        y = 1.0
        for t in range(120):
            g0, g1 = grad_half(w, 0), grad_half(w, 1)
            if quantized:
                xs = jnp.stack([g0, g1])
                y = max(float(2 * jnp.max(jnp.abs(g0 - g1))) * 1.5, 1e-8)
                res = mean_estimation_star(xs, y, LatticeQ(q=16),
                                           jax.random.PRNGKey(100 + t),
                                           CompressorCtx(y=y))
                g = res.est[0]
            else:
                g = (g0 + g1) / 2
            w = w - 0.05 * g
            losses.append(float(jnp.mean((A @ w - b) ** 2)))
        return losses

    lq = run(True)
    ref = run(False)
    assert lq[-1] < 1e-3, f"quantized GD must converge, got {lq[-1]}"
    assert lq[-1] < 50 * max(ref[-1], 1e-9) + 1e-3
