"""Hadamard rotation tests (paper §6, Lemma 24 / Theorem 5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rotation as R


def test_fwht_involutive_orthonormal():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 1024))
    y = R.fwht_jnp(x)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(R.fwht_jnp(y)), np.asarray(x),
                               rtol=1e-4, atol=1e-5)


def test_rotate_unrotate_roundtrip_nonpow2():
    d = 300   # padded to 512 internally
    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    diag = R.rotation_keypair(jax.random.PRNGKey(2), d)
    xr = R.rotate(x, diag)
    assert xr.shape[-1] == 512
    back = R.unrotate(xr, diag, d)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-4,
                               atol=1e-5)


def test_lemma24_linf_concentration():
    """||HDx||_inf = O(d^-1/2 ||x||_2 sqrt(log nd)) — test for a spike vector
    (worst case for the unrotated l_inf)."""
    d = 4096
    x = jnp.zeros((d,)).at[7].set(100.0)        # single spike: linf = 100
    bounds = []
    for seed in range(20):
        diag = R.rotation_keypair(jax.random.PRNGKey(seed), d)
        xr = R.rotate(x, diag)
        bounds.append(float(jnp.max(jnp.abs(xr))))
    # after rotation the spike spreads: linf ~ 100/sqrt(d) * sqrt(2 log d)
    expect = 100 / np.sqrt(d) * np.sqrt(2 * np.log(d * 20))
    assert max(bounds) < 3 * expect, (max(bounds), expect)
    assert max(bounds) < 10.0       # versus 100 unrotated


def test_rotated_coord_bound_holds_whp():
    """rotated_coord_bound(l2, d, beta) upper-bounds |HDx|_inf empirically,
    is sublinear in d (the l2/sqrt(d) shape), and tightens with beta."""
    d = 1024
    x = jnp.zeros((d,)).at[3].set(1.0)          # unit-l2 spike (worst case)
    bound = R.rotated_coord_bound(1.0, d, beta=1e-3)
    for seed in range(30):
        diag = R.rotation_keypair(jax.random.PRNGKey(seed), d)
        assert float(jnp.max(jnp.abs(R.rotate(x, diag)))) <= bound
    assert bound < 0.2                          # ~ sqrt(2 ln(2d/beta) / d)
    assert R.rotated_coord_bound(1.0, 4 * d) < bound
    assert R.rotated_coord_bound(1.0, d, beta=1e-6) > bound
    assert R.rotated_coord_bound(2.0, d) == 2 * R.rotated_coord_bound(1.0, d)
