"""repro.obs (ISSUE 8): metrics registry semantics, the np.percentile-exact
quantile, span-tree causal completeness over the real engine/tree paths,
exporter round-trips (Chrome trace JSON, Prometheus text), the flight
recorder's bounded ring on an injected saturation REJECT, and the
registry-backed DISPATCH_COUNTS / RoundStats views.

Everything here must also hold with observability DISABLED (the default):
the last test class asserts the off-path stays dark — no spans, no global
instruments — while stats accounting is unchanged.
"""
import json

import numpy as np
import pytest

import repro.obs as obs
from repro.agg.server import AggServer
from repro.agg.sim import OpenLoopConfig, fleet_payloads, run_open_loop
from repro.agg.transport import frame as wire
from repro.agg.tree import AggTree
from repro.dist.collectives import QSyncConfig
from repro.kernels import ops as K
from repro.obs import (Counter, FlightRecorder, Histogram, Registry, Tracer,
                       check_round, quantile)


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with observability off and empty."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


def _spec(round_id=1, d=256, bucket=64, q=16, seed=0, max_attempts=4,
          mtu=0):
    return wire.RoundSpec(round_id=round_id, d=d,
                          cfg=QSyncConfig(q=q, bucket=bucket), y0=0.5,
                          seed=seed, max_attempts=max_attempts, mtu=mtu)


def _fleet(spec, n, seed=0):
    rng = np.random.RandomState(seed)
    base = rng.randn(spec.d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(n, spec.d).astype(np.float32)
    return base, xs


# ---------------------------------------------------------------- registry

class TestRegistry:
    def test_get_or_create_identity(self):
        reg = Registry()
        c1 = reg.counter("hits", path="a")
        c2 = reg.counter("hits", path="a")
        assert c1 is c2
        assert reg.counter("hits", path="b") is not c1
        c1.inc(); c1.inc(3)
        assert reg.value("hits", path="a") == 4
        assert reg.value("hits", path="b") == 0

    def test_kind_clash_raises(self):
        reg = Registry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_reset_preserves_identity(self):
        reg = Registry()
        c = reg.counter("n")
        c.inc(5)
        reg.reset()
        assert c.value == 0
        assert reg.counter("n") is c     # same object, zeroed in place

    def test_gauge_set_max(self):
        reg = Registry()
        g = reg.gauge("peak")
        g.set_max(3.0); g.set_max(1.0); g.set_max(7.0)
        assert g.value == 7.0

    def test_histogram_merge(self):
        a = Histogram.from_values([1.0, 2.0, 3.0])
        b = Histogram.from_values([4.0, 5.0])
        a.merge(b)
        assert a.count == 5
        assert a.total == 15.0
        assert a.vmin == 1.0 and a.vmax == 5.0
        assert a.quantile(50) == 3.0

    def test_disabled_returns_noop(self):
        assert not obs.enabled()
        c = obs.counter("dark")
        assert c is obs.NOOP
        c.inc(100)                       # swallowed, never registered
        assert obs.registry().value("dark") is None

    def test_enabled_returns_live(self):
        obs.enable(trace=False, record=False)
        obs.counter("lit").inc(2)
        assert obs.registry().value("lit") == 2


# ---------------------------------------------------------------- quantile

class TestQuantile:
    def test_matches_np_percentile_exactly(self):
        rng = np.random.RandomState(7)
        for n in (1, 2, 3, 7, 100, 999):
            vals = rng.randn(n).tolist()
            for p in (0, 10, 50, 90, 99, 100):
                assert quantile(vals, p) == float(np.percentile(vals, p)), \
                    (n, p)

    def test_matches_np_median(self):
        rng = np.random.RandomState(1)
        for n in (1, 4, 5, 1000):
            vals = rng.randn(n).tolist()
            assert quantile(vals, 50) == pytest.approx(
                float(np.median(vals)), abs=1e-12)

    def test_histogram_exact_below_reservoir_cap(self):
        rng = np.random.RandomState(3)
        vals = rng.randn(500).tolist()
        h = Histogram.from_values(vals)
        assert h.exact
        for p in (50, 99):
            assert h.quantile(p) == float(np.percentile(vals, p))

    def test_histogram_interpolates_beyond_cap(self):
        rng = np.random.RandomState(4)
        vals = np.abs(rng.randn(10_000)).tolist()
        h = Histogram.from_values(vals)
        assert not h.exact
        # bucket interpolation: right order of magnitude, monotone in p
        p50, p99 = h.quantile(50), h.quantile(99)
        assert 0 < p50 < p99 <= h.vmax
        assert abs(p50 - float(np.percentile(vals, 50))) < 0.25


# -------------------------------------------------------------- span trees

class TestSpanTrees:
    def test_flat_round_complete(self):
        obs.enable()
        spec = _spec()
        base, xs = _fleet(spec, 6)
        server = AggServer(spec, base)
        for p in fleet_payloads(spec, xs):
            server.receive(p)
        server.drain()
        server.finalize()
        problems = check_round(obs.tracer(), spec.round_id,
                               accepted=sorted(server.accepted_clients))
        assert problems == []

    def test_check_round_flags_missing_client(self):
        obs.enable()
        spec = _spec()
        base, xs = _fleet(spec, 4)
        server = AggServer(spec, base)
        for p in fleet_payloads(spec, xs):
            server.receive(p)
        server.drain()
        server.finalize()
        ghost = 999
        problems = check_round(obs.tracer(), spec.round_id,
                               accepted=[ghost])
        assert any(f"client {ghost}" in p for p in problems)

    def test_check_round_no_round_span(self):
        assert check_round(Tracer(), 42) == ["round 42: no round span"]

    def test_tree_round_complete_with_fold(self):
        obs.enable()
        spec = _spec(round_id=7, seed=3)
        base, xs = _fleet(spec, 12, seed=3)
        tree = AggTree(spec, base, fanout=4, tiers=1)
        for p in fleet_payloads(spec, xs):
            tree.ingest_frame(p)
        tree.tick()
        tree.seal()
        for _ in range(8):
            tree.tick()
            if tree.published():
                break
        pt = tree.published()[0]
        assert len(pt.accepted) == 12
        problems = check_round(obs.tracer(), spec.round_id,
                               accepted=pt.accepted, require_fold=True)
        assert problems == []

    @pytest.mark.slow
    def test_open_loop_every_round_complete(self):
        # reduced offered load, IDENTICAL shapes (d/bucket/mtu) to the
        # bench config so the jit caches are shared across the suite
        cfg = OpenLoopConfig(rate=60.0, duration=0.25, flash_at=(),
                             adversarial=0, churn_frac=0.0,
                             straggle_frac=0.1, loss=0.02)
        obs.enable()
        rep = run_open_loop(cfg, check_parity=False)
        assert rep.rounds >= 2
        tr = obs.tracer()
        for pr in rep.published:
            assert check_round(tr, pr.round_id, accepted=pr.accepted) == []
        # span times are the sim's virtual event times, not wall time
        root = tr.get(("round", rep.published[0].round_id))
        assert root.end is not None and root.end <= 10.0

    def test_virtual_clock_monotonic(self):
        tr = Tracer()
        tr.feed_time(5.0)
        tr.feed_time(2.0)                # stale feed: ignored
        assert tr.now() == 5.0

    def test_end_idempotent(self):
        tr = Tracer()
        sp = tr.begin("r", key=("round", 1))
        tr.feed_time(1.0)
        tr.end(("round", 1))
        tr.feed_time(2.0)
        tr.end(("round", 1))             # second end is a no-op
        assert sp.end == 1.0


# --------------------------------------------------------------- exporters

class TestExporters:
    def _traced_round(self):
        obs.enable()
        spec = _spec()
        base, xs = _fleet(spec, 4)
        server = AggServer(spec, base)
        for p in fleet_payloads(spec, xs):
            server.receive(p)
        server.drain()
        server.finalize()
        return spec, server

    def test_chrome_trace_schema(self):
        self._traced_round()
        events = json.loads(obs.export.chrome_trace(obs.tracer()))
        assert isinstance(events, list) and events
        phases = {e["ph"] for e in events}
        assert "X" in phases and "i" in phases
        for e in events:
            assert isinstance(e["name"], str)
            if e["ph"] == "X":
                assert e["dur"] >= 0
                assert "ts" in e
            if e["ph"] == "i":
                assert e["s"] == "t"

    def test_chrome_trace_no_orphans(self):
        self._traced_round()
        events = json.loads(obs.export.chrome_trace(obs.tracer()))
        ids = {e["args"]["span_id"] for e in events
               if e["ph"] in ("X", "i")}
        for e in events:
            if e["ph"] not in ("X", "i"):
                continue
            parent = e["args"].get("parent_id")
            assert parent is None or parent in ids, e

    def test_chrome_trace_nesting_balanced(self):
        # every complete event must fit inside its parent's time range
        self._traced_round()
        tr = obs.tracer()
        by_id = {s.span_id: s for s in tr.spans}
        for s in tr.spans:
            assert s.end is not None, s          # all closed after finalize
            if s.parent_id is not None:
                p = by_id[s.parent_id]
                assert p.start <= s.start and s.end <= p.end, (s, p)

    def test_prometheus_round_trip(self):
        obs.enable(trace=False, record=False)
        obs.counter("rx_total", path="frame").inc(7)
        obs.gauge("peak_bytes").set(123.5)
        h = obs.histogram("lat_s")
        for v in (0.01, 0.02, 0.5):
            h.observe(v)
        text = obs.export.prometheus_text(obs.registry())
        assert "# TYPE rx_total counter" in text
        parsed = obs.export.parse_prometheus_text(text)
        assert parsed[("rx_total", (("path", "frame"),))] == 7.0
        assert parsed[("peak_bytes", ())] == 123.5
        assert parsed[("lat_s_count", ())] == 3.0
        assert parsed[("lat_s_sum", ())] == pytest.approx(0.53)
        # cumulative buckets: the +Inf bucket equals the count
        assert parsed[("lat_s_bucket", (("le", "+Inf"),))] == 3.0

    def test_prometheus_label_values_quoted(self):
        obs.enable(trace=False, record=False)
        obs.counter("x", round=1).inc(4)
        parsed = obs.export.parse_prometheus_text(
            obs.export.prometheus_text(obs.registry()))
        assert parsed[("x", (("round", "1"),))] == 4.0


# ---------------------------------------------------------- flight recorder

class TestFlightRecorder:
    def test_ring_keeps_exactly_last_n(self):
        rec = FlightRecorder(capacity=4)
        for i in range(7):
            rec.record({"i": i})
        dump = rec.trigger("unit_test", at=1.0)
        assert [e["i"] for e in dump.events] == [3, 4, 5, 6]
        assert dump.reason == "unit_test"
        assert rec.last_dump() is dump

    def test_saturation_reject_dumps_last_n(self):
        # individually-decodable payloads (max|k| ~ 5 < q_max/2 = 8) whose
        # tier fold exceeds the escalation cap's coordinate range: the
        # second child at each tier draws a saturation REJECT, which must
        # trigger a flight-recorder dump holding exactly the last N spans
        cap = 4
        obs.enable(recorder_capacity=cap)
        spec = _spec(round_id=9, d=64, max_attempts=1)
        base = np.zeros(64, dtype=np.float32)
        xs = np.full((4, 64), 0.3, dtype=np.float32)
        tree = AggTree(spec, base, fanout=2, tiers=1)
        for p in fleet_payloads(spec, xs):
            tree.ingest_frame(p)
        tree.tick()
        tree.seal()
        for _ in range(8):
            tree.tick()
        dump = obs.recorder().last_dump()
        assert dump is not None
        assert dump.reason == "saturation_reject"
        assert dump.attrs["round"] == spec.round_id
        assert len(dump.events) == cap
        # the tier kept folding after the dump: saturated stat recorded
        pubs = tree.published()
        assert pubs and len(pubs[0].accepted) == 2

    def test_trigger_noop_when_disabled(self):
        assert obs.trigger("anything", at=0.0) is None
        assert obs.recorder().last_dump() is None


# -------------------------------------------------- registry-backed views

class TestDispatchCounts:
    def test_dict_view(self):
        K.reset_dispatch_counts()
        assert dict(K.DISPATCH_COUNTS.items()) == {
            "lattice_decode": 0, "lattice_decode_batched": 0}
        assert K.DISPATCH_COUNTS == {"lattice_decode": 0,
                                     "lattice_decode_batched": 0}
        assert "lattice_decode" in K.DISPATCH_COUNTS
        assert K.DISPATCH_COUNTS.get("nope", -1) == -1
        assert len(K.DISPATCH_COUNTS) == 2
        assert set(K.DISPATCH_COUNTS) == set(K.DISPATCH_COUNTS.keys())

    def test_counts_survive_registry_reset(self):
        # ops.py caches the Counter objects at import; the registry hands
        # back the SAME instrument for the same (name, labels), and
        # Registry.reset() zeroes it in place instead of orphaning it
        K.reset_dispatch_counts()
        c = obs.registry().counter("kernel_dispatch",
                                   kernel="lattice_decode_batched")
        c.inc(3)
        assert K.DISPATCH_COUNTS["lattice_decode_batched"] == 3
        obs.registry().reset()
        assert K.DISPATCH_COUNTS["lattice_decode_batched"] == 0
        c.inc()
        assert K.DISPATCH_COUNTS["lattice_decode_batched"] == 1
        K.reset_dispatch_counts()


class TestStatsFromRegistry:
    def test_round_stats_match_registry(self):
        obs.enable(trace=False, record=False)
        spec = _spec(round_id=5)
        base, xs = _fleet(spec, 6)
        server = AggServer(spec, base)
        for p in fleet_payloads(spec, xs):
            server.receive(p)
        server.drain()
        server.finalize()
        st = server.stats
        assert st.received == 6
        assert st.accepted == 6
        # the same numbers are readable straight off the global registry
        vals = {i.name: i.value for i in obs.registry().instruments()
                if i.name.startswith("agg_round_")
                and i.labels.get("round") == spec.round_id}
        assert vals.get("agg_round_received") == 6
        assert vals.get("agg_round_accepted") == 6
        assert vals.get("agg_round_bytes_in", 0) > 0

    def test_stats_identical_when_disabled(self):
        # scopes fall back to a detached registry: accounting unchanged
        spec = _spec(round_id=6)
        base, xs = _fleet(spec, 5)
        server = AggServer(spec, base)
        for p in fleet_payloads(spec, xs):
            server.receive(p)
        server.drain()
        server.finalize()
        assert server.stats.received == 5
        assert server.stats.accepted == 5
        # the global registry never saw this round's scope
        assert not any(i.name.startswith("agg_round_")
                       and i.labels.get("round") == spec.round_id
                       for i in obs.registry().instruments())


# ------------------------------------------------------- disabled-by-default

class TestDisabledByDefault:
    def test_off_path_stays_dark(self):
        assert not obs.enabled()
        spec = _spec(round_id=8)
        base, xs = _fleet(spec, 4)
        server = AggServer(spec, base)
        for p in fleet_payloads(spec, xs):
            server.receive(p)
        server.drain()
        server.finalize()
        assert obs.tracer().spans == []
        assert obs.recorder().snapshot() == []
        assert not any(i.labels.get("round") == spec.round_id
                       for i in obs.registry().instruments())
