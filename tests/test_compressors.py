"""Compressor zoo: roundtrip sanity + wire accounting for every baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import (make_compressor, CompressorCtx,
                                    ALL_COMPRESSORS, ef_roundtrip, EFSign)
from repro.core import rotation as R

D = 512


def _ctx():
    diag = R.rotation_keypair(jax.random.PRNGKey(0), D)
    return CompressorCtx(y=1.0, diag=diag)


@pytest.mark.parametrize("name", ALL_COMPRESSORS)
def test_roundtrip_and_wire_bytes(name):
    comp = make_compressor(name)
    x = jax.random.normal(jax.random.PRNGKey(1), (D,))
    z = comp.roundtrip(x, _ctx(), jax.random.PRNGKey(2))
    assert z.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(z)))
    wb = comp.wire_bytes(D)
    assert 0 < wb
    if name not in ("fp32",):
        assert wb < D * 4, f"{name} should compress below fp32"


@pytest.mark.parametrize("name", ["qsgd_l2", "hadamard", "terngrad"])
def test_stochastic_unbiasedness(name):
    comp = make_compressor(name)
    x = jax.random.normal(jax.random.PRNGKey(3), (D,))
    acc = jnp.zeros_like(x)
    n = 600
    for i in range(n):
        acc = acc + comp.roundtrip(x, _ctx(), jax.random.PRNGKey(10 + i))
    dev = float(jnp.max(jnp.abs(acc / n - x)))
    assert dev < 0.3, f"{name} deviates {dev}"


def test_error_feedback_reduces_bias():
    comp = EFSign()
    x = jax.random.normal(jax.random.PRNGKey(4), (D,)) * 0.1
    err = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    for i in range(400):
        xh, err = ef_roundtrip(comp, x, err, _ctx())
        acc = acc + xh
    # EF: long-run average of compressed signal converges to the signal
    assert float(jnp.max(jnp.abs(acc / 400 - x))) < 0.08
