"""End-to-end driver (deliverable b): train a ~100M-param LM with
lattice-quantized gradient synchronization for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

On this CPU container a full run takes tens of minutes; pass --steps 20 for
a quick check.  On a pod: --mesh 16x16.
"""
import sys

sys.argv = [sys.argv[0], "--preset", "100m", "--steps",
            sys.argv[sys.argv.index("--steps") + 1] if "--steps" in sys.argv
            else "300", "--seq", "128", "--batch", "4", "--lr", "1e-3",
            "--ckpt-dir", "/tmp/repro_train_100m"]
from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    main()
