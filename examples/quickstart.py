"""Quickstart: lattice-quantized distributed mean estimation in 30 lines.

The paper's core claim, live: with inputs concentrated far from the origin,
LQ's error tracks the *pairwise distance* y while norm-based quantizers pay
for the norm.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (LatticeQ, QSGD, CompressorCtx, mean_estimation_star)

n, d = 8, 1024
mu = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 1000.0   # huge norm
xs = mu + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (n, d))
y = float(2 * jnp.max(jnp.abs(xs - xs.mean(0))))               # tiny spread

res = mean_estimation_star(xs, y, LatticeQ(q=16), jax.random.PRNGKey(2),
                           CompressorCtx(y=y))
err_lq = float(jnp.linalg.norm(res.est[0] - xs.mean(0)))

qs = QSGD(qlevel=16)
zs = [qs.roundtrip(xs[i], CompressorCtx(), jax.random.PRNGKey(3 + i))
      for i in range(n)]
err_qsgd = float(jnp.linalg.norm(jnp.stack(zs).mean(0) - xs.mean(0)))

print(f"input norm        : {float(jnp.linalg.norm(xs[0])):12.2f}")
print(f"input spread (y)  : {y:12.4f}")
print(f"LQ (4 bits/coord) : error {err_lq:10.4f}   <- tracks y")
print(f"QSGD (same bits)  : error {err_qsgd:10.4f}   <- pays for the norm")
print(f"advantage         : {err_qsgd/err_lq:10.1f}x")
assert err_lq * 10 < err_qsgd
