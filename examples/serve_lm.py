"""Batched greedy decoding with the sharded-KV-cache serve path.

    PYTHONPATH=src python examples/serve_lm.py
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.models.sharding import ShardCtx
from repro.models import transformer as T
from repro.models import serve as SV

cfg = registry.smoke_config("glm4-9b")
ctx = ShardCtx(tp=1, dp=1)
mesh = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
params = T.init_params(cfg, ctx, jax.random.PRNGKey(0))
B, S_max = 4, 64
cache = SV.cache_zeros(cfg, ctx, B, S_max)
step = SV.make_serve_step(cfg, ctx)

@partial(jax.shard_map, mesh=mesh, in_specs=(P(),) * 5,
         out_specs=(P(), P()), check_vma=False)
def f(params, cache, tokens, pos, key):
    return step(params, cache, tokens, pos, key)

f = jax.jit(f)
toks = jnp.array([[1], [2], [3], [4]], jnp.int32)
seqs = [toks[:, 0]]
key = jax.random.PRNGKey(7)
for t in range(16):
    nxt, cache = f(params, cache, toks, jnp.int32(t), key)
    toks = nxt[:, None]
    seqs.append(nxt)
out = np.stack([np.asarray(s) for s in seqs], axis=1)
print("greedy decodes (untrained weights -> arbitrary but deterministic):")
for b in range(B):
    print(f"  seq {b}: {out[b].tolist()}")
