"""Federated DME over the repro.agg byte protocol: hundreds of clients ship
packed-lattice payloads (real ``bytes``: header + uint32 color words + sides
sidecar + §5 checksum + CRC) to a streaming aggregation server.

Demonstrates, and fails loudly if violated (this script is a CI smoke):

  * a full round under drops, duplicate deliveries, stragglers, corrupt
    frames and out-of-bound adversarial clients — the latter recovered via
    the RobustAgreement escalation handshake (q <- q^2, granularity fixed);
  * the server's integer-space accumulator is bit-deterministic under
    arrival order;
  * wire cost ~ d*log2(q)/8 bytes per client vs 4d for f32;
  * the chunked transport (ISSUE 5): one round with the MTU forcing >= 4
    chunks per client is bit-identical to the single-frame round, and a
    lossy round recovers dropped/corrupt chunks at exactly the lost
    chunks' wire cost (selective retransmit, never a payload resend);
  * windowed streaming decode (ISSUE 9, wire v5): a credit-paced round
    (``window=2``) under 10% loss converges via ack/credit + selective
    RESEND + timeout recovery, exercises window stalls, shrinks the
    pending store below the sealed path's high-water, and publishes a
    mean bit-identical to the sealed batched-decode drain.

    PYTHONPATH=src python examples/federated_dme.py                 # flat
    PYTHONPATH=src python examples/federated_dme.py --topology tree # tree

``--topology tree`` runs the hierarchical smoke instead (ISSUE 7): the same
traffic through a 2-tier fanout-8 :class:`repro.agg.tree.AggTree` — edge
tiers sum packed payloads without decoding, the root issues the single
batched decode — asserted bit-identical to the flat server, driven purely
through the :class:`repro.agg.api.AggNode` verbs.
"""
import argparse

import numpy as np

from repro.agg.transport import frame as wire
from repro.agg.client import AggClient
from repro.agg.server import AggServer
from repro.agg.sim import SimConfig, fleet_frames, fleet_payloads, run_round

args = argparse.ArgumentParser(description=__doc__)
args.add_argument("--topology", choices=("flat", "tree"), default="flat",
                  help="flat: the single-server round mix (default); "
                       "tree: the 2-tier fanout-8 hierarchical smoke")
args = args.parse_args()


def tree_smoke() -> None:
    """Tree-vs-flat bit-parity over chunked traffic, AggNode verbs only."""
    from repro.agg.tree import AggTree
    from repro.kernels import ops as K

    fanout, tiers, n_clients = 8, 2, 96
    spec = SimConfig(d=2048, bucket=256, y0=0.5, mtu=256, seed=11,
                     round_id=3).spec()
    rng = np.random.RandomState(11)
    base = 2.0 * rng.randn(spec.d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(n_clients, spec.d).astype(np.float32)
    frames = fleet_frames(spec, xs)
    n_chunks = len(frames[0])

    flat = AggServer(spec, base)
    for fs in frames:
        for f in fs:
            flat.ingest_frame(f)
    flat.tick()
    flat.seal()
    pf = flat.published()[0]

    before = K.DISPATCH_COUNTS.get("lattice_decode_batched", 0)
    tree = AggTree(spec, base, fanout=fanout, tiers=tiers)
    for fs in frames:
        for f in fs:
            tree.ingest_frame(f)
    tree.tick()
    tree.seal()
    for _ in range(8):
        tree.tick()
        if tree.published():
            break
    else:
        raise SystemExit("tree did not publish")
    pt = tree.published()[0]
    decodes = K.DISPATCH_COUNTS.get("lattice_decode_batched", 0) - before
    spaces = len({t.forwarded_q for t in tree.layers[0]
                  if t.forwarded_q is not None})
    print(f"tree: {n_clients} clients x {n_chunks} chunks -> "
          f"{fanout ** tiers} edge + {fanout} regional tiers -> root")
    print(f"  root ingress {tree.root_ingress_payloads} payloads "
          f"(fanout bound {fanout}); {decodes} decode dispatches over "
          f"{spaces} color space(s), all at the root")
    if pt.accepted != pf.accepted:
        raise SystemExit("tree accepted set differs from flat")
    if not np.array_equal(pt.mean.view(np.uint32), pf.mean.view(np.uint32)):
        raise SystemExit("tree mean is not bit-identical to flat")
    if tree.root_ingress_payloads > fanout:
        raise SystemExit("root saw more payloads than the fanout bound")
    if decodes != spaces:
        raise SystemExit(f"{decodes} decode dispatches for {spaces} color "
                         f"spaces (tiers must not decode; the root decodes "
                         f"once per color space)")
    print("hierarchical tree aggregation (2 tiers, fanout 8): OK")


if args.topology == "tree":
    tree_smoke()
    raise SystemExit(0)

# --- one simulated round with the full failure mix ------------------------
cfg = SimConfig(clients=256, d=4096, q=16, bucket=512, y0=0.5,
                drop=0.02, duplicate=0.05, straggle=0.25,
                corrupt=2, truncate=1, adversarial=3, extreme=1, seed=0)
rep = run_round(cfg)
s = rep.stats
fp32_bytes = 4 * cfg.d
print(f"round: {cfg.clients} clients d={cfg.d} q={cfg.q}")
print(f"  accepted={s.accepted} dropped={len(rep.dropped_clients)} "
      f"duplicates={s.duplicates} wire_rejects={s.rejected_wire} "
      f"decode_failures={s.decode_failures} nacks={s.nacks_sent} "
      f"gave_up={s.gave_up} drains={s.drains}")
print(f"  escalation recovered clients: {sorted(rep.escalated_clients)}")
print(f"  mean vs exact (accepted subset): max_err={rep.max_err:.5f}")
print(f"  wire: {rep.bytes_per_client:.0f} B/client vs fp32 {fp32_bytes} B "
      f"({fp32_bytes / rep.bytes_per_client:.1f}x compression)")

if rep.max_err > 2 * wire.y_at_attempt(cfg.spec(), 0):
    raise SystemExit("round mean error exceeds the lattice bound")
if not rep.escalated_clients:
    raise SystemExit("adversarial clients were not recovered via escalation")
if s.gave_up != cfg.extreme:
    raise SystemExit("extreme out-of-bound client was not dropped")

# --- bit-determinism under arrival order ----------------------------------
spec = wire.RoundSpec(round_id=9, d=2048,
                      cfg=cfg.spec().cfg, y0=0.5, seed=3)
rng = np.random.RandomState(0)
base = rng.randn(spec.d).astype(np.float32)
xs = base[None] + 0.02 * rng.randn(32, spec.d).astype(np.float32)
payloads = fleet_payloads(spec, xs)
means = []
for order_seed in (1, 2):
    server = AggServer(spec, base)
    for i in np.random.RandomState(order_seed).permutation(len(payloads)):
        server.ingest_frame(payloads[i])
    means.append(server.finalize()[0])
if not np.array_equal(means[0], means[1]):
    raise SystemExit("server mean is not invariant to arrival order")
print("arrival-order bit-determinism: OK")

# --- the per-client protocol object matches the fleet encoder -------------
if AggClient(spec, 5, xs[5]).payload() != payloads[5]:
    raise SystemExit("AggClient payload differs from the fleet encoder")
print("client/fleet payload parity: OK")

# --- chunked transport (ISSUE 5 CI smoke): mtu forces >= 4 chunks/client --
import dataclasses

from repro.agg.sim import run_chunked_lossy

chunked_spec = dataclasses.replace(spec, mtu=256)
frames = fleet_frames(chunked_spec, xs)
n_chunks = len(frames[0])
if n_chunks < 4:
    raise SystemExit(f"mtu=256 only produced {n_chunks} chunks/client")
server_c = AggServer(chunked_spec, base)
order = [(c, k) for k in range(n_chunks) for c in range(len(frames))]
for c, k in (order[i] for i in np.random.RandomState(5).permutation(
        len(order))):
    server_c.ingest_frame(frames[c][k])
mean_c, stats_c = server_c.finalize()
if stats_c.accepted != len(frames):
    raise SystemExit("chunked round lost clients")
if not np.array_equal(mean_c, means[0]):
    raise SystemExit("chunked round mean != single-frame round mean")
hdr = stats_c.peak_unvalidated_bytes
print(f"chunked round: {n_chunks} chunks/client (mtu=256), bit-identical "
      f"to single-frame; peak unvalidated buffer {hdr} B "
      f"(vs {len(payloads[5])} B monolithic)")
from repro.core import wire_accounting as WA

if hdr > WA.FRAME_HEADER_BYTES + chunked_spec.mtu:
    raise SystemExit("transport staged more than one frame of "
                     "unvalidated bytes")

rep_l = run_chunked_lossy(clients=8, d=2048, bucket=256, mtu=512,
                          n_drop=2, n_corrupt=1, seed=1)
print(f"lossy chunked round: {rep_l.retransmit_bytes} B retransmitted for "
      f"{len(rep_l.mean)}-d payloads (full resend would be "
      f"{rep_l.full_resend_bytes} B)")
print("chunked transport: OK")

# --- windowed streaming round (v5, ISSUE 9 CI smoke): credit-paced clients
# under loss against the streaming-decode server, bit-identical to the
# sealed batched drain over the same accepted clients ----------------------
wspec = dataclasses.replace(chunked_spec, window=2)
server_w = AggServer(wspec, base)
clients_w = [AggClient(wspec, cid, xs[cid]) for cid in range(len(xs))]
rng_w = np.random.RandomState(9)
outbox = [(c, f) for c in clients_w for f in c.send_frames()]
for _ in range(400):
    nxt = []
    for c, f in outbox:
        if rng_w.rand() < 0.10:
            continue                         # lost on the wire
        rb = server_w.receive(f)
        nxt.extend((c, g) for g in c.handle_response(rb))
    outbox = nxt
    if all(c.acked for c in clients_w):
        break
    if not outbox:                           # quiet: timeout recovery
        for c in clients_w:
            rr = server_w.resend_request(c.client_id)
            if rr is not None:
                outbox.extend((c, g) for g in c.handle_response(rr))
            else:
                outbox.extend((c, f) for f in c.retransmit_frames())
if not all(c.acked for c in clients_w):
    raise SystemExit("windowed round did not converge under loss")
mean_w, stats_w = server_w.finalize()
sealed_w = AggServer(wspec, base, streaming=False)
for fs in fleet_frames(wspec, xs):
    for f in fs:
        sealed_w.receive(f)
mean_sealed, stats_sealed = sealed_w.finalize()
if not np.array_equal(mean_w.view(np.uint32), mean_sealed.view(np.uint32)):
    raise SystemExit("streaming mean != sealed batched-decode mean")
stalls = sum(c.window_stalls for c in clients_w)
if stalls == 0:
    raise SystemExit("lossy windowed round exercised no window stalls")
if stats_w.peak_pending_store_bytes >= stats_sealed.peak_pending_store_bytes:
    raise SystemExit("streaming decode did not shrink the pending store")
print(f"windowed streaming round: window={wspec.window} 10% loss, "
      f"{stalls} window stalls; pending store "
      f"{stats_w.peak_pending_store_bytes} B vs sealed "
      f"{stats_sealed.peak_pending_store_bytes} B; bit-identical to "
      f"sealed drain")
print("windowed streaming decode: OK")

# --- anchored multi-round service (RoundSpec v2, ISSUE 4 CI smoke) --------
# Three rounds over a drifting large-norm population: round k+1's anchor is
# round k's published mean (digest-pinned in the spec) and its per-bucket y
# comes from round k's decode telemetry.
from repro.agg import rounds as AR
from repro.agg.service import AggService, ServiceConfig

rng = np.random.RandomState(7)
d3 = 2048
mu = 1e6 * rng.randn(d3).astype(np.float32)
svc = AggService(ServiceConfig(d=d3, bucket=256, y0=0.5, seed=7),
                 anchor0=mu.copy())
published = []
for rnd in range(3):
    mu = mu + 0.02 * rng.randn(d3).astype(np.float32)
    xs3 = mu[None] + 0.02 * rng.randn(48, d3).astype(np.float32)
    spec3, anchor3 = svc.begin_round()
    if published:
        # the contract under test: round k+1's anchor IS round k's mean
        if spec3.anchor_digest != AR.anchor_digest(published[-1]):
            raise SystemExit("anchor digest does not chain round means")
        if not np.array_equal(anchor3, published[-1]):
            raise SystemExit("round anchor is not the previous mean")
    server3 = svc.make_server()
    for p in fleet_payloads(spec3, xs3, anchor=anchor3):
        server3.ingest_frame(p)
    mean3, stats3 = svc.end_round(server3)
    published.append(mean3)
    exact3 = xs3.astype(np.float64).mean(0)
    err3 = float(np.abs(mean3 - exact3).max())
    print(f"  anchored round {spec3.round_id}: accepted={stats3.accepted} "
          f"digest={spec3.anchor_digest:#010x} max_err={err3:.5f} "
          f"y_mean={float(np.mean(spec3.y_np())):.3f}")
    if stats3.accepted != 48:
        raise SystemExit("anchored round lost clients")
    if err3 > 2 * float(np.max(spec3.y_np())):
        raise SystemExit("anchored round error exceeds the lattice bound")
print("anchored multi-round digest chain: OK")
