"""Federated DME over the repro.agg byte protocol: hundreds of clients ship
packed-lattice payloads (real ``bytes``: header + uint32 color words + sides
sidecar + §5 checksum + CRC) to a streaming aggregation server.

Demonstrates, and fails loudly if violated (this script is a CI smoke):

  * a full round under drops, duplicate deliveries, stragglers, corrupt
    frames and out-of-bound adversarial clients — the latter recovered via
    the RobustAgreement escalation handshake (q <- q^2, granularity fixed);
  * the server's integer-space accumulator is bit-deterministic under
    arrival order;
  * wire cost ~ d*log2(q)/8 bytes per client vs 4d for f32.

    PYTHONPATH=src python examples/federated_dme.py
"""
import numpy as np

from repro.agg import wire
from repro.agg.client import AggClient
from repro.agg.server import AggServer
from repro.agg.sim import SimConfig, fleet_payloads, run_round

# --- one simulated round with the full failure mix ------------------------
cfg = SimConfig(clients=256, d=4096, q=16, bucket=512, y0=0.5,
                drop=0.02, duplicate=0.05, straggle=0.25,
                corrupt=2, truncate=1, adversarial=3, extreme=1, seed=0)
rep = run_round(cfg)
s = rep.stats
fp32_bytes = 4 * cfg.d
print(f"round: {cfg.clients} clients d={cfg.d} q={cfg.q}")
print(f"  accepted={s.accepted} dropped={len(rep.dropped_clients)} "
      f"duplicates={s.duplicates} wire_rejects={s.rejected_wire} "
      f"decode_failures={s.decode_failures} nacks={s.nacks_sent} "
      f"gave_up={s.gave_up} drains={s.drains}")
print(f"  escalation recovered clients: {sorted(rep.escalated_clients)}")
print(f"  mean vs exact (accepted subset): max_err={rep.max_err:.5f}")
print(f"  wire: {rep.bytes_per_client:.0f} B/client vs fp32 {fp32_bytes} B "
      f"({fp32_bytes / rep.bytes_per_client:.1f}x compression)")

if rep.max_err > 2 * wire.y_at_attempt(cfg.spec(), 0):
    raise SystemExit("round mean error exceeds the lattice bound")
if not rep.escalated_clients:
    raise SystemExit("adversarial clients were not recovered via escalation")
if s.gave_up != cfg.extreme:
    raise SystemExit("extreme out-of-bound client was not dropped")

# --- bit-determinism under arrival order ----------------------------------
spec = wire.RoundSpec(round_id=9, d=2048,
                      cfg=cfg.spec().cfg, y0=0.5, seed=3)
rng = np.random.RandomState(0)
base = rng.randn(spec.d).astype(np.float32)
xs = base[None] + 0.02 * rng.randn(32, spec.d).astype(np.float32)
payloads = fleet_payloads(spec, xs)
means = []
for order_seed in (1, 2):
    server = AggServer(spec, base)
    for i in np.random.RandomState(order_seed).permutation(len(payloads)):
        server.receive(payloads[i])
    means.append(server.finalize()[0])
if not np.array_equal(means[0], means[1]):
    raise SystemExit("server mean is not invariant to arrival order")
print("arrival-order bit-determinism: OK")

# --- the per-client protocol object matches the fleet encoder -------------
if AggClient(spec, 5, xs[5]).payload() != payloads[5]:
    raise SystemExit("AggClient payload differs from the fleet encoder")
print("client/fleet payload parity: OK")
