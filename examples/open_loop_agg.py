"""Open-loop continuous-round aggregation: Poisson arrivals driving the
event-driven AggEngine (ISSUE 6 CI smoke).

Clients arrive as an open-loop Poisson process (plus a flash crowd) on a
virtual clock; the engine keeps several rounds live at once — the open
round admits whoever shows up, sealed rounds drain their stragglers in the
background — cutting over on quorum-or-deadline, expiring stragglers
through the RESEND budget, and answering every inadmissible frame with a
non-terminal RETRY.  Demonstrates, and fails loudly if violated:

  * >= 3 rounds concurrently live under the offered load (overlapping
    intake + drain — the lockstep coordinator can never exceed 1);
  * every published round's mean is bit-identical to a lockstep replay
    over exactly that round's accepted clients (arrival order, chunking,
    loss and round interleaving cannot move the mean) — asserted inside
    run_open_loop for every round;
  * no benign client ever draws a terminal verdict: admission timing,
    backpressure and expiry are all non-terminal (PR 5's invariant);
  * the engine's virtual-clock rounds/sec beats the lockstep coordinator
    on the IDENTICAL arrival trace;
  * with observability enabled (ISSUE 8), every published round yields a
    causally complete span tree — client encode → chunk frames → session
    reassembly → drain → publish — validated by repro.obs.check_round, and
    both exporters (Chrome trace JSON, Prometheus text) render the run.

    PYTHONPATH=src python examples/open_loop_agg.py
"""
import json

import repro.obs as obs
from repro.agg.api import AggNode
from repro.agg.engine import AggEngine
from repro.agg.server import AggServer
from repro.agg.service import AggService
from repro.agg.sim import OpenLoopConfig, run_lockstep, run_open_loop
from repro.agg.tree import AggTree

cfg = OpenLoopConfig()   # ~160 arrivals at 250/s + a 32-client flash crowd,
                         # chunked mtu=64, 3% frame loss, churn + stragglers

# every aggregation endpoint is the same AggNode to a driver (ISSUE 7): the
# open-loop harness below drives the engine purely through
# ingest_frame/tick/published, and could be handed a flat server or a tree
svc = AggService(cfg.service_config())
eng = AggEngine(svc, cfg.engine_config(), now=0.0)
spec0, anchor0 = eng.open_round.spec, eng.open_round.anchor
for node in (eng, AggServer(spec0, anchor0),
             AggTree(spec0, anchor0, fanout=2)):
    if not isinstance(node, AggNode):
        raise SystemExit(f"{type(node).__name__} does not satisfy AggNode")
print("AggNode protocol: engine, flat server and tree are interchangeable")

rep = run_open_loop(cfg, check_parity=True)

print(f"open loop: {rep.clients_arrived} arrivals at {cfg.rate:.0f}/s "
      f"(+{cfg.flash_size} flash), mtu={cfg.mtu}, loss={cfg.loss:.0%}")
print(f"  rounds published: {rep.rounds}  accepted: {rep.accepted_total} "
      f"clients  expired stragglers: {rep.expired_total}")
print(f"  max concurrently-live rounds: {rep.max_live_rounds}  "
      f"non-terminal RETRYs: {rep.retried_total}  "
      f"chunk RESENDs: {rep.resends_total}")
print(f"  round latency p50={rep.p50_latency * 1e3:.0f}ms "
      f"p99={rep.p99_latency * 1e3:.0f}ms  anchor staleness "
      f"mean={rep.mean_staleness * 1e3:.0f}ms "
      f"(<= {rep.max_staleness_rounds} rounds)")
print(f"  throughput: {rep.rounds_per_s:.2f} rounds/s over "
      f"{rep.makespan:.2f}s virtual makespan")

if rep.rounds < 3:
    raise SystemExit("fewer than 3 rounds published under offered load")
if rep.max_live_rounds < 3:
    raise SystemExit(
        f"only {rep.max_live_rounds} rounds were concurrently live; the "
        f"overlapping-drain engine should sustain >= 3 under this load")
if rep.expired_total == 0:
    raise SystemExit("no straggler was expired — injected churn not seen")
if rep.resends_total == 0:
    raise SystemExit("no chunk RESEND was sent — injected loss not seen")
print("per-round lockstep replay parity: OK (bit-identical, all rounds)")
print("no terminal verdict for any benign client: OK")

lock = run_lockstep(cfg)
print(f"lockstep baseline on the same trace: {lock.rounds} rounds, "
      f"{lock.rounds_per_s:.2f} rounds/s, worst admission queueing "
      f"{lock.queue_delay_max * 1e3:.0f}ms")
if rep.rounds_per_s <= lock.rounds_per_s:
    raise SystemExit(
        f"engine ({rep.rounds_per_s:.2f} rounds/s) did not beat lockstep "
        f"({lock.rounds_per_s:.2f} rounds/s) on the same trace")
print(f"engine vs lockstep: {rep.rounds_per_s / lock.rounds_per_s:.2f}x "
      f"rounds/s on the identical arrival trace")
print("OPEN_LOOP_SMOKE_OK")

# ---- observability smoke (ISSUE 8): rerun the identical trace with full
# tracing/metrics/recording on and audit every published round's span tree
obs.enable()
try:
    rep_t = run_open_loop(cfg, check_parity=False)
    tr = obs.tracer()
    for pr in rep_t.published:
        problems = obs.check_round(tr, pr.round_id, accepted=pr.accepted)
        if problems:
            raise SystemExit(
                f"round {pr.round_id} span tree incomplete: {problems}")
    events = json.loads(obs.export.chrome_trace(tr))
    prom = obs.export.prometheus_text(obs.registry())
    if not events or "# TYPE" not in prom:
        raise SystemExit("exporters produced no output for a traced run")
    print(f"observability: {rep_t.rounds} published rounds, all span trees "
          f"causally complete ({len(tr.spans)} spans, 0 dropped)"
          if tr.dropped == 0 else
          f"observability: {tr.dropped} spans dropped")
    print(f"  exporters: chrome trace {len(events)} events, prometheus "
          f"{len(obs.registry())} instruments")
finally:
    obs.disable()
    obs.reset()
print("OBS_SMOKE_OK")
