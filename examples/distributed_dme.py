"""Distributed DME on 8 (emulated) devices: the production quantized
collectives inside shard_map — star (all-gather) vs butterfly topology.

Each topology runs twice: packed=True (the production wire path — fused
Pallas encode/decode moving bits_for_q(q)-bit colors in uint32 words plus
the per-bucket sides sidecar) and packed=False (unpacked jnp colors, the
oracle).  The two must agree *bitwise* — this script is part of the tier-1
CI gate (scripts/ci.sh) and fails loudly if they drift.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/distributed_dme.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import (QSyncConfig, butterfly_allreduce_mean,
                                    allgather_allreduce_mean,
                                    wire_bytes_butterfly, wire_bytes_allgather)

mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
n = 1 << 16
base = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 100.0
xs = base + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (8, n))
mean = xs.mean(0)
y = float(2 * jnp.max(jnp.abs(xs - mean)))
cfg = QSyncConfig(q=16, bucket=4096)
y_b = jnp.full((n // cfg.bucket,), y)
key = jax.random.PRNGKey(42)


def run(fn, cfg):
    @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
             out_specs=P("data"), check_vma=False)
    def f(xl):
        out, aux = fn(xl.reshape(-1), y_b, key, "data", cfg)
        return out.reshape(1, -1)
    return np.asarray(jax.jit(f)(xs))


for fn, wire_fn, n_msgs, tag in (
        (butterfly_allreduce_mean, wire_bytes_butterfly, 3,
         "butterfly (tree-analogue)"),
        (allgather_allreduce_mean, wire_bytes_allgather, 7,
         "all-gather (star-analogue)")):
    out = run(fn, cfg)                                       # packed wire
    out_ref = run(fn, dataclasses.replace(cfg, packed=False))
    if not np.array_equal(out, out_ref):
        raise SystemExit(f"{tag}: packed wire path diverged from the "
                         f"unpacked jnp oracle")
    err = np.max(np.abs(out - np.asarray(mean)[None]))
    wire = wire_fn(n, 8, cfg)
    fp32 = n_msgs * n * 4        # the same topology moving f32 vectors
    print(f"{tag:28s}: identical={np.all(out == out[0])} packed==jnp=True "
          f"max_err={err:.5f} wire={wire/1024:.0f}KiB vs fp32 "
          f"{fp32/1024:.0f}KiB ({fp32/wire:.1f}x compression)")
