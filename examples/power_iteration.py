"""Paper §9.5: distributed power iteration with quantized partial products.

    PYTHONPATH=src python examples/power_iteration.py
"""
from benchmarks.bench_power_iteration import run

for n in (2, 8):
    for name in ("fp32", "lq", "rlq", "qsgd"):
        align = run(name, n=n, iters=30)
        print(f"n={n:2d} {name:5s}: |<x, v1>| = {align:.4f}")
